//! The sharded serve plane: N live masters, each owning a disjoint machine
//! partition with its own scheduler, `SchedIndex`, and event queue, behind
//! a [`ShardRouter`] that spreads submissions across them and a
//! [`ShardedHandle`] exposing the same submit/shutdown surface as a single
//! [`MasterHandle`].
//!
//! Shards share **no** cluster state: a submission is admitted, scheduled,
//! and completed entirely inside one shard, so the only cross-shard
//! artifacts are the router's load reads (the per-shard `queued_tasks`
//! gauge) and the aggregated [`ServeReport`].  See DESIGN.md §15.

use std::sync::{mpsc, Mutex};
use std::time::Duration;

use crate::config::{RoutePolicy, ServeConfig, SimConfig};
use crate::stats::Pcg64;

use super::backpressure::Backpressure;
use super::master::{Master, MasterHandle, Report, Submission, SubmitResult};
use super::metrics::{Gauge, MetricsRegistry, Sampler, TimeSeries};

/// Split `machines` into `shards` disjoint partitions: `machines / shards`
/// each, with the remainder spread one-per-shard from the front, so
/// partition sizes differ by at most one.
pub fn partition_machines(machines: usize, shards: usize) -> Vec<usize> {
    assert!(shards >= 1, "at least one shard");
    assert!(shards <= machines, "every shard needs >= 1 machine");
    let q = machines / shards;
    let r = machines % shards;
    (0..shards).map(|i| q + usize::from(i < r)).collect()
}

/// SplitMix64 finalizer: a full-avalanche 64-bit mix, so any field of the
/// submission flips every output bit with probability ~1/2.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Routes submissions to shards.
///
/// * [`RoutePolicy::Hash`]: seeded modulo hash of the submission's shape
///   (task count, mean duration, alpha) — stateless and deterministic, so
///   identical submissions always land on the same shard.
/// * [`RoutePolicy::P2c`]: power of two choices — draw two shards from a
///   seeded RNG (exactly two draws per submission) and send to the one
///   whose `queued_tasks` gauge reads lower, first draw winning ties.
pub struct ShardRouter {
    policy: RoutePolicy,
    seed: u64,
    rng: Pcg64,
    loads: Vec<Gauge>,
}

impl ShardRouter {
    /// `loads[i]` must be shard i's `queued_tasks` gauge (shared with the
    /// shard's registry, so reads see the live backlog).
    pub fn new(policy: RoutePolicy, seed: u64, loads: Vec<Gauge>) -> Self {
        assert!(!loads.is_empty(), "router needs >= 1 shard");
        ShardRouter { policy, seed, rng: Pcg64::new(seed, 0x70c2), loads }
    }

    /// Pick the shard for `sub`.
    pub fn route(&mut self, sub: &Submission) -> usize {
        let n = self.loads.len();
        if n == 1 {
            return 0;
        }
        match self.policy {
            RoutePolicy::Hash => {
                let h = mix64(
                    self.seed
                        ^ mix64(sub.num_tasks as u64)
                        ^ mix64(sub.mean_duration.to_bits()).rotate_left(17)
                        ^ mix64(sub.alpha.to_bits()).rotate_left(31),
                );
                (h % n as u64) as usize
            }
            RoutePolicy::P2c => {
                let a = self.rng.uniform_u64(0, n as u64 - 1) as usize;
                let b = self.rng.uniform_u64(0, n as u64 - 1) as usize;
                // strict <: ties (including frozen gauges) keep the first
                // draw, so an unloaded deployment degrades to uniform
                if self.loads[b].get() < self.loads[a].get() {
                    b
                } else {
                    a
                }
            }
        }
    }
}

/// Configuration + spawner for a sharded deployment — the N-master
/// counterpart of [`Master`].
pub struct ShardedMaster {
    cfg: SimConfig,
    pub serve: ServeConfig,
    /// Wall-clock duration of one scheduling slot (every shard ticks at
    /// the same rate).
    pub tick: Duration,
    /// Max slots each shard runs after shutdown while draining.
    pub drain_slots: u64,
    /// Per-shard backpressure override; `None` sizes watermarks from each
    /// shard's own partition (the [`Master::new`] default).
    pub backpressure: Option<Backpressure>,
    /// Fixed-interval metrics sampling across all shard registries;
    /// `None` disables the sampler thread.
    pub sample_every: Option<Duration>,
    /// Ring capacity of the sampled time series.
    pub sample_cap: usize,
}

impl ShardedMaster {
    pub fn new(cfg: SimConfig, serve: ServeConfig) -> Self {
        ShardedMaster {
            cfg,
            serve,
            tick: Duration::from_millis(5),
            drain_slots: 5000,
            backpressure: None,
            sample_every: None,
            sample_cap: 4096,
        }
    }

    /// Spawn one master thread per shard.  Shard i gets partition size
    /// `partition_machines(machines, shards)[i]` and seed
    /// `base.wrapping_add(i)` — shard 0 keeps the base seed, so a 1-shard
    /// deployment is bit-identical to a plain [`Master`].
    pub fn spawn(self) -> Result<ShardedHandle, String> {
        self.serve.validate(self.cfg.machines)?;
        if self.serve.shards > 1 && !self.cfg.machine_classes.is_empty() {
            return Err(
                "sharding a heterogeneous machine-class layout is not supported: \
                 class counts cannot be split across disjoint partitions yet"
                    .to_string(),
            );
        }
        let parts = partition_machines(self.cfg.machines, self.serve.shards);
        let mut shards = Vec::with_capacity(parts.len());
        let mut metrics = Vec::with_capacity(parts.len());
        for (i, &m) in parts.iter().enumerate() {
            let mut cfg = self.cfg.clone();
            cfg.machines = m;
            cfg.seed = self.cfg.seed.wrapping_add(i as u64);
            let mut master = Master::new(cfg);
            master.tick = self.tick;
            master.drain_slots = self.drain_slots;
            if let Some(bp) = self.backpressure {
                master.backpressure = bp;
            }
            metrics.push(master.metrics.clone());
            shards.push(master.spawn()?);
        }
        let loads = metrics.iter().map(|m| m.gauge("queued_tasks")).collect();
        let router = ShardRouter::new(self.serve.route, self.serve.route_seed, loads);
        let sampler = match self.sample_every {
            Some(every) => Some(Sampler::spawn(metrics.clone(), every, self.sample_cap)?),
            None => None,
        };
        Ok(ShardedHandle { router: Mutex::new(router), shards, metrics, sampler })
    }
}

/// Client handle over the whole deployment: routes submissions, fans
/// batches out to all shards in parallel, and aggregates shutdown reports.
pub struct ShardedHandle {
    router: Mutex<ShardRouter>,
    shards: Vec<MasterHandle>,
    metrics: Vec<MetricsRegistry>,
    sampler: Option<Sampler>,
}

impl ShardedHandle {
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Shard i's metrics registry (shared with its master thread).
    pub fn metrics(&self, shard: usize) -> &MetricsRegistry {
        &self.metrics[shard]
    }

    /// Route one submission and submit it; returns `(shard, result)`.
    pub fn submit(&self, sub: Submission) -> Result<(usize, SubmitResult), String> {
        let shard = self.router.lock().unwrap().route(&sub);
        let result = self.shards[shard].submit(sub)?;
        Ok((shard, result))
    }

    /// Route a burst: one router pass, then one batched channel round trip
    /// per shard — every shard's batch is **sent before any reply is
    /// awaited**, so admission runs on all shards concurrently.  Results
    /// come back in submission order, tagged with the serving shard.
    pub fn submit_batch(
        &self,
        subs: &[Submission],
    ) -> Result<Vec<(usize, SubmitResult)>, String> {
        let n = self.shards.len();
        let mut routed = Vec::with_capacity(subs.len());
        let mut per_shard: Vec<Vec<Submission>> = vec![Vec::new(); n];
        {
            let mut router = self.router.lock().unwrap();
            for sub in subs {
                let shard = router.route(sub);
                routed.push(shard);
                per_shard[shard].push(*sub);
            }
        }
        let mut pending: Vec<Option<mpsc::Receiver<Vec<SubmitResult>>>> = Vec::with_capacity(n);
        for (shard, batch) in per_shard.into_iter().enumerate() {
            if batch.is_empty() {
                pending.push(None);
            } else {
                pending.push(Some(self.shards[shard].send_batch(batch)?));
            }
        }
        let mut replies: Vec<std::vec::IntoIter<SubmitResult>> = Vec::with_capacity(n);
        for rx in pending {
            replies.push(match rx {
                Some(rx) => rx
                    .recv()
                    .map_err(|_| "master dropped reply".to_string())?
                    .into_iter(),
                None => Vec::new().into_iter(),
            });
        }
        Ok(routed
            .into_iter()
            .map(|shard| {
                let r = replies[shard].next().expect("per-shard reply count matches routing");
                (shard, r)
            })
            .collect())
    }

    /// Put **every** shard into drain before joining any (so shards drain
    /// concurrently), then aggregate the per-shard reports and stop the
    /// sampler.
    pub fn shutdown(self) -> Result<ServeReport, String> {
        for s in &self.shards {
            s.begin_shutdown();
        }
        let mut reports = Vec::with_capacity(self.shards.len());
        for s in self.shards {
            reports.push(s.shutdown()?);
        }
        let series = self.sampler.map(|s| s.stop());
        Ok(ServeReport { shards: reports, series })
    }
}

/// Aggregate shutdown report: the per-shard [`Report`]s plus the sampled
/// metrics time series (when sampling was enabled).
#[derive(Debug)]
pub struct ServeReport {
    pub shards: Vec<Report>,
    pub series: Option<TimeSeries>,
}

impl ServeReport {
    /// Jobs completed across all shards — retained records plus any the
    /// capped (`max_resident_jobs`) masters drained into their sketches.
    pub fn completed(&self) -> usize {
        self.shards
            .iter()
            .map(|r| {
                r.completed.len() + r.streamed.as_ref().map_or(0, |s| s.drained as usize)
            })
            .sum()
    }

    pub fn rejected(&self) -> u64 {
        self.shards.iter().map(|r| r.rejected).sum()
    }

    pub fn slots(&self) -> u64 {
        self.shards.iter().map(|r| r.slots).sum()
    }

    /// Machine-weighted mean utilization across shards (each shard's
    /// utilization is already normalized by its own partition size).
    pub fn utilization(&self) -> f64 {
        let total: usize = self.shards.iter().map(|r| r.machines).sum();
        if total == 0 {
            return 0.0;
        }
        self.shards.iter().map(|r| r.utilization * r.machines as f64).sum::<f64>()
            / total as f64
    }

    /// Plain-text per-shard breakdown for the CLI.
    pub fn table(&self) -> String {
        let mut out = String::from("shard  machines  completed  rejected  utilization\n");
        for (i, r) in self.shards.iter().enumerate() {
            let done =
                r.completed.len() + r.streamed.as_ref().map_or(0, |s| s.drained as usize);
            out.push_str(&format!(
                "{i:>5}  {:>8}  {:>9}  {:>8}  {:>11.4}\n",
                r.machines, done, r.rejected, r.utilization
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::SchedulerKind;

    fn sub(num_tasks: u32, mean_duration: f64) -> Submission {
        Submission { num_tasks, mean_duration, alpha: 2.0 }
    }

    #[test]
    fn partition_spreads_remainder() {
        assert_eq!(partition_machines(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(partition_machines(8, 2), vec![4, 4]);
        assert_eq!(partition_machines(7, 1), vec![7]);
        assert_eq!(partition_machines(5, 5), vec![1, 1, 1, 1, 1]);
        for (m, s) in [(1000, 3), (17, 4), (64, 5)] {
            let p = partition_machines(m, s);
            assert_eq!(p.iter().sum::<usize>(), m);
            assert!(p.iter().max().unwrap() - p.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    #[should_panic]
    fn partition_rejects_more_shards_than_machines() {
        partition_machines(2, 3);
    }

    fn loads(n: usize) -> Vec<Gauge> {
        let reg = MetricsRegistry::new();
        (0..n).map(|i| reg.gauge(&format!("q{i}"))).collect()
    }

    #[test]
    fn hash_routing_is_deterministic_and_shape_keyed() {
        let mut r1 = ShardRouter::new(RoutePolicy::Hash, 7, loads(4));
        let mut r2 = ShardRouter::new(RoutePolicy::Hash, 7, loads(4));
        let s = sub(42, 2.5);
        let shard = r1.route(&s);
        for _ in 0..10 {
            assert_eq!(r1.route(&s), shard, "identical submissions pin one shard");
            assert_eq!(r2.route(&s), shard, "routing is stateless");
        }
        // different shapes spread: at least two distinct shards among many
        let mut seen = std::collections::BTreeSet::new();
        for t in 1..=64 {
            seen.insert(r1.route(&sub(t, 1.0)));
        }
        assert!(seen.len() > 1, "hash must not collapse to one shard");
    }

    #[test]
    fn single_shard_routes_to_zero() {
        let mut r = ShardRouter::new(RoutePolicy::P2c, 9, loads(1));
        assert_eq!(r.route(&sub(3, 1.0)), 0);
    }

    #[test]
    fn p2c_prefers_less_loaded_shard() {
        let ls = loads(2);
        ls[0].set(1000);
        ls[1].set(0);
        let mut r = ShardRouter::new(RoutePolicy::P2c, 1, ls);
        let mut counts = [0usize; 2];
        for t in 0u32..200 {
            counts[r.route(&sub(t % 7 + 1, 1.0))] += 1;
        }
        assert!(
            counts[1] > counts[0],
            "p2c must favor the unloaded shard: {counts:?}"
        );
        // shard 0 is still reachable (both draws landing on it)
        assert!(counts[0] > 0, "double-draw collisions keep the hot shard reachable");
    }

    #[test]
    fn serve_report_aggregates() {
        let mk = |machines: usize, rejected: u64, utilization: f64| Report {
            completed: Vec::new(),
            rejected,
            machines,
            slots: 10,
            slots_fired: 10,
            slots_skipped: 0,
            utilization,
            streamed: None,
        };
        let rep = ServeReport { shards: vec![mk(30, 2, 0.5), mk(10, 3, 0.9)], series: None };
        assert_eq!(rep.completed(), 0);
        assert_eq!(rep.rejected(), 5);
        assert_eq!(rep.slots(), 20);
        assert!((rep.utilization() - 0.6).abs() < 1e-12); // (30*0.5 + 10*0.9)/40
        assert!(rep.table().lines().count() == 3);
    }

    #[test]
    fn two_shards_complete_submissions() {
        let mut cfg = SimConfig::default();
        cfg.machines = 32;
        cfg.horizon = f64::INFINITY;
        cfg.use_runtime = false;
        cfg.scheduler = SchedulerKind::Sda;
        let mut sm = ShardedMaster::new(cfg, ServeConfig { shards: 2, ..Default::default() });
        sm.tick = Duration::from_micros(200);
        sm.sample_every = Some(Duration::from_secs(3600));
        let handle = sm.spawn().unwrap();
        assert_eq!(handle.shards(), 2);
        let subs: Vec<Submission> = (1..=10).map(|i| sub(i, 1.0)).collect();
        let results = handle.submit_batch(&subs).unwrap();
        assert_eq!(results.len(), 10);
        assert!(results.iter().all(|(_, r)| r.is_accepted()));
        let report = handle.shutdown().unwrap();
        assert_eq!(report.completed(), 10, "every accepted job drains somewhere");
        assert_eq!(report.shards.len(), 2);
        assert_eq!(report.shards.iter().map(|r| r.machines).sum::<usize>(), 32);
        let series = report.series.as_ref().unwrap();
        assert_eq!(series.len(), 2, "stop() samples each shard once");
        assert_eq!(
            series.aggregate_latest().counters.get("jobs_submitted"),
            Some(&10)
        );
    }
}
