//! The sharded serve plane: N live masters, each owning a disjoint machine
//! partition with its own scheduler, `SchedIndex`, and event queue, behind
//! a [`ShardRouter`] that spreads submissions across them and a
//! [`ShardedHandle`] exposing the same submit/shutdown surface as a single
//! [`MasterHandle`].
//!
//! Shards share **no** cluster state: a submission is admitted, scheduled,
//! and completed entirely inside one shard, so the only cross-shard
//! artifacts are the router's load reads (the per-shard `queued_tasks`
//! gauge) and the aggregated [`ServeReport`].  See DESIGN.md §15.
//!
//! The plane is **self-healing** (DESIGN.md §17): every master thread runs
//! under `catch_unwind` with a liveness flag, a supervisor embedded in
//! [`ShardedHandle`] respawns a dead shard with a fresh master on the same
//! derived seed and replays its un-acked submissions from a per-shard
//! in-flight ledger, routed sends retry with capped exponential backoff +
//! jitter, and the router excludes down shards from hash/p2c picks until
//! they recover.  When the restart budget is exhausted — or a shard's
//! backlog is past the shed watermark — submissions get a structured
//! [`SubmitResult::Shed`] instead of an error or a hung call.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex, MutexGuard};
use std::time::Duration;

use crate::config::{RoutePolicy, ServeConfig, SimConfig};
use crate::stats::Pcg64;
use crate::workload::MachineEvent;

use super::backpressure::Backpressure;
use super::master::{Master, MasterHandle, Report, Submission, SubmitResult};
use super::metrics::{Gauge, MetricsRegistry, Sampler, TimeSeries};

/// Split `machines` into `shards` disjoint partitions: `machines / shards`
/// each, with the remainder spread one-per-shard from the front, so
/// partition sizes differ by at most one.
pub fn partition_machines(machines: usize, shards: usize) -> Vec<usize> {
    assert!(shards >= 1, "at least one shard");
    assert!(shards <= machines, "every shard needs >= 1 machine");
    let q = machines / shards;
    let r = machines % shards;
    (0..shards).map(|i| q + usize::from(i < r)).collect()
}

/// SplitMix64 finalizer: a full-avalanche 64-bit mix, so any field of the
/// submission flips every output bit with probability ~1/2.
fn mix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    x ^ (x >> 31)
}

/// Routes submissions to shards.
///
/// * [`RoutePolicy::Hash`]: seeded modulo hash of the submission's shape
///   (task count, mean duration, alpha) — stateless and deterministic, so
///   identical submissions always land on the same shard.
/// * [`RoutePolicy::P2c`]: power of two choices — draw two shards from a
///   seeded RNG (exactly two draws per submission) and send to the one
///   whose `queued_tasks` gauge reads lower, first draw winning ties.
pub struct ShardRouter {
    policy: RoutePolicy,
    seed: u64,
    rng: Pcg64,
    loads: Vec<Gauge>,
    /// Per-shard liveness flags, shared with each master thread (flipped
    /// false by its drop guard on any exit) and with the supervisor (set
    /// true again on respawn).  Down shards are excluded from picks.
    ups: Vec<Arc<AtomicBool>>,
}

impl ShardRouter {
    /// `loads[i]` must be shard i's `queued_tasks` gauge (shared with the
    /// shard's registry, so reads see the live backlog) and `ups[i]` its
    /// liveness flag.
    pub fn new(
        policy: RoutePolicy,
        seed: u64,
        loads: Vec<Gauge>,
        ups: Vec<Arc<AtomicBool>>,
    ) -> Self {
        assert!(!loads.is_empty(), "router needs >= 1 shard");
        assert_eq!(loads.len(), ups.len(), "one liveness flag per shard");
        ShardRouter { policy, seed, rng: Pcg64::new(seed, 0x70c2), loads, ups }
    }

    fn up(&self, shard: usize) -> bool {
        self.ups[shard].load(Ordering::Relaxed)
    }

    /// Pick the shard for `sub`.  Down shards are excluded while at least
    /// one shard is up; with **every** shard down the router falls back to
    /// the all-up pick so the delivery path still has a restart target
    /// (the supervisor may resurrect it) instead of routing nowhere.
    /// With all shards up the pick — and the p2c RNG draw count — is
    /// bit-identical to the pre-supervisor router.
    pub fn route(&mut self, sub: &Submission) -> usize {
        let n = self.loads.len();
        if n == 1 {
            return 0;
        }
        match self.policy {
            RoutePolicy::Hash => {
                let h = mix64(
                    self.seed
                        ^ mix64(sub.num_tasks as u64)
                        ^ mix64(sub.mean_duration.to_bits()).rotate_left(17)
                        ^ mix64(sub.alpha.to_bits()).rotate_left(31),
                );
                let h = (h % n as u64) as usize;
                // linear probe past down shards, wrapping once
                (0..n).map(|i| (h + i) % n).find(|&s| self.up(s)).unwrap_or(h)
            }
            RoutePolicy::P2c => {
                let up: Vec<usize> = (0..n).filter(|&s| self.up(s)).collect();
                let pick2 = |rng: &mut Pcg64, loads: &[Gauge], pool: &[usize]| {
                    let a = pool[rng.uniform_u64(0, pool.len() as u64 - 1) as usize];
                    let b = pool[rng.uniform_u64(0, pool.len() as u64 - 1) as usize];
                    // strict <: ties (including frozen gauges) keep the
                    // first draw, so an unloaded deployment degrades to
                    // uniform
                    if loads[b].get() < loads[a].get() {
                        b
                    } else {
                        a
                    }
                };
                match up.len() {
                    0 => {
                        let all: Vec<usize> = (0..n).collect();
                        pick2(&mut self.rng, &self.loads, &all)
                    }
                    1 => up[0], // no draw: a lone survivor needs no choice
                    _ => pick2(&mut self.rng, &self.loads, &up),
                }
            }
        }
    }
}

/// Configuration + spawner for a sharded deployment — the N-master
/// counterpart of [`Master`].
pub struct ShardedMaster {
    cfg: SimConfig,
    pub serve: ServeConfig,
    /// Wall-clock duration of one scheduling slot (every shard ticks at
    /// the same rate).
    pub tick: Duration,
    /// Max slots each shard runs after shutdown while draining.
    pub drain_slots: u64,
    /// Per-shard backpressure override; `None` sizes watermarks from each
    /// shard's own partition (the [`Master::new`] default).
    pub backpressure: Option<Backpressure>,
    /// Fixed-interval metrics sampling across all shard registries;
    /// `None` disables the sampler thread.
    pub sample_every: Option<Duration>,
    /// Ring capacity of the sampled time series.
    pub sample_cap: usize,
    /// Supervisor budget: how many times a dead shard is respawned before
    /// it is abandoned (later submissions routed to it are shed).
    pub max_restarts: u32,
    /// Retries of a routed send (restart + replay) before the in-flight
    /// ledger is shed with structured rejects.
    pub max_retries: u32,
    /// First retry backoff; doubles per attempt up to [`retry_cap`]
    /// (plus up to 50% seeded jitter).
    ///
    /// [`retry_cap`]: Self::retry_cap
    pub retry_base: Duration,
    pub retry_cap: Duration,
    /// Front-door overload shedding: a submission routed to a shard whose
    /// `queued_tasks` gauge reads above this many tasks gets
    /// [`SubmitResult::Shed`] without a channel round trip — the sharded
    /// tier above the per-master watermark [`Backpressure`], for callers
    /// that prefer an instant structured reject over blocking on a
    /// saturated shard.  `None` disables the fast path.
    pub shed_watermark: Option<usize>,
    /// Scripted machine churn (`replay --machine-events`): global machine
    /// ids over the whole deployment, split across the contiguous shard
    /// partitions at spawn (shard 0 owns machines `[0, p0)`, shard 1
    /// `[p0, p0+p1)`, ...) and handed to each master as partition-local
    /// events.  A supervisor respawn re-stages the shard's script.
    pub machine_events: Vec<MachineEvent>,
}

impl ShardedMaster {
    pub fn new(cfg: SimConfig, serve: ServeConfig) -> Self {
        ShardedMaster {
            cfg,
            serve,
            tick: Duration::from_millis(5),
            drain_slots: 5000,
            backpressure: None,
            sample_every: None,
            sample_cap: 4096,
            max_restarts: 8,
            max_retries: 4,
            retry_base: Duration::from_millis(1),
            retry_cap: Duration::from_millis(50),
            shed_watermark: None,
            machine_events: Vec::new(),
        }
    }

    /// Spawn one master thread per shard.  Shard i gets partition size
    /// `partition_machines(machines, shards)[i]` and seed
    /// `base.wrapping_add(i)` — shard 0 keeps the base seed, so a 1-shard
    /// deployment is bit-identical to a plain [`Master`].
    pub fn spawn(self) -> Result<ShardedHandle, String> {
        self.serve.validate(self.cfg.machines)?;
        if self.serve.shards > 1 && !self.cfg.machine_classes.is_empty() {
            return Err(
                "sharding a heterogeneous machine-class layout is not supported: \
                 class counts cannot be split across disjoint partitions yet"
                    .to_string(),
            );
        }
        if let Some(bad) =
            self.machine_events.iter().find(|e| e.machine as usize >= self.cfg.machines)
        {
            return Err(format!(
                "--machine-events: machine {} out of range (cluster has {})",
                bad.machine, self.cfg.machines
            ));
        }
        let parts = partition_machines(self.cfg.machines, self.serve.shards);
        let mut slots = Vec::with_capacity(parts.len());
        let mut metrics = Vec::with_capacity(parts.len());
        let mut ups = Vec::with_capacity(parts.len());
        let mut offset = 0usize;
        for (i, &m) in parts.iter().enumerate() {
            let mut cfg = self.cfg.clone();
            cfg.machines = m;
            cfg.seed = self.cfg.seed.wrapping_add(i as u64);
            // this shard's slice of the churn script, rebased to local ids
            let events: Vec<MachineEvent> = self
                .machine_events
                .iter()
                .filter(|e| (offset..offset + m).contains(&(e.machine as usize)))
                .map(|e| MachineEvent { machine: (e.machine as usize - offset) as u32, ..*e })
                .collect();
            offset += m;
            let mut master = Master::new(cfg.clone());
            master.tick = self.tick;
            master.drain_slots = self.drain_slots;
            if let Some(bp) = self.backpressure {
                master.backpressure = bp;
            }
            master.machine_events = events.clone();
            metrics.push(master.metrics.clone());
            ups.push(master.alive.clone());
            let handle = master.spawn()?;
            slots.push(Mutex::new(ShardSlot {
                handle: Some(handle),
                ledger: Vec::new(),
                restarts: 0,
                cfg,
                events,
            }));
        }
        let loads: Vec<Gauge> = metrics.iter().map(|m| m.gauge("queued_tasks")).collect();
        let router =
            ShardRouter::new(self.serve.route, self.serve.route_seed, loads.clone(), ups.clone());
        let sampler = match self.sample_every {
            Some(every) => Some(Sampler::spawn(metrics.clone(), every, self.sample_cap)?),
            None => None,
        };
        Ok(ShardedHandle {
            router: Mutex::new(router),
            slots,
            metrics,
            loads,
            ups,
            sampler,
            tick: self.tick,
            drain_slots: self.drain_slots,
            backpressure: self.backpressure,
            max_restarts: self.max_restarts,
            max_retries: self.max_retries,
            retry_base: self.retry_base,
            retry_cap: self.retry_cap,
            shed_watermark: self.shed_watermark,
            jitter_rng: Mutex::new(Pcg64::new(self.serve.route_seed, 0xb0ff)),
        })
    }
}

/// One shard's supervised state: the live handle (None only after a failed
/// respawn), the in-flight ledger of submissions sent but not yet acked,
/// the restart budget consumed so far, and the per-shard config a respawn
/// reuses — same partition size, same derived seed, so a restarted shard
/// is a "fresh seeded master" in exactly the [`ShardedMaster::spawn`]
/// sense.
struct ShardSlot {
    handle: Option<MasterHandle>,
    ledger: Vec<Submission>,
    restarts: u32,
    cfg: SimConfig,
    /// Partition-local machine-events script, re-staged on every respawn.
    events: Vec<MachineEvent>,
}

/// Client handle over the whole deployment: routes submissions, fans
/// batches out to all shards in parallel, supervises shard death (respawn
/// + ledger replay + backoff), and aggregates shutdown reports.
pub struct ShardedHandle {
    router: Mutex<ShardRouter>,
    slots: Vec<Mutex<ShardSlot>>,
    metrics: Vec<MetricsRegistry>,
    /// `queued_tasks` gauge per shard — the shed-watermark fast path reads
    /// these without touching the registry locks.
    loads: Vec<Gauge>,
    /// Liveness flag per shard, shared with the master threads and router.
    ups: Vec<Arc<AtomicBool>>,
    sampler: Option<Sampler>,
    tick: Duration,
    drain_slots: u64,
    backpressure: Option<Backpressure>,
    max_restarts: u32,
    max_retries: u32,
    retry_base: Duration,
    retry_cap: Duration,
    shed_watermark: Option<usize>,
    /// Seeded jitter for retry backoff (stream 0xb0ff off the route seed),
    /// so chaos tests replay the same sleep schedule.
    jitter_rng: Mutex<Pcg64>,
}

impl ShardedHandle {
    pub fn shards(&self) -> usize {
        self.slots.len()
    }

    /// Shard i's metrics registry (shared with its master thread; survives
    /// supervisor respawns).
    pub fn metrics(&self, shard: usize) -> &MetricsRegistry {
        &self.metrics[shard]
    }

    /// Is shard i's master thread currently running?  Flips false the
    /// moment the thread exits (panic included) and true again when the
    /// supervisor respawns it.
    pub fn shard_alive(&self, shard: usize) -> bool {
        self.ups[shard].load(Ordering::Relaxed)
    }

    /// Restarts consumed from shard i's supervisor budget.
    pub fn restarts(&self, shard: usize) -> u32 {
        self.slots[shard].lock().unwrap().restarts
    }

    /// Chaos hook: panic shard i's master thread (asynchronous — poll
    /// [`shard_alive`](Self::shard_alive) to observe the death).  The next
    /// routed send detects the corpse and triggers the supervisor.
    pub fn inject_crash(&self, shard: usize) -> Result<(), String> {
        match &self.slots[shard].lock().unwrap().handle {
            Some(h) => h.inject_crash(),
            None => Err("shard abandoned".to_string()),
        }
    }

    /// Route one submission and submit it; returns `(shard, result)`.
    pub fn submit(&self, sub: Submission) -> Result<(usize, SubmitResult), String> {
        Ok(self
            .submit_batch(std::slice::from_ref(&sub))?
            .pop()
            .expect("one result per submission"))
    }

    /// Route a burst: one router pass, then one batched channel round trip
    /// per shard — every shard's batch is **sent before any reply is
    /// awaited**, so admission runs on all shards concurrently.  Results
    /// come back in submission order, tagged with the serving shard.
    ///
    /// Fault paths (each yields a structured per-submission result, never
    /// a hung call):
    /// * routed shard past the shed watermark → [`SubmitResult::Shed`]
    ///   without a channel round trip;
    /// * shard died before/while serving the batch → the supervisor
    ///   respawns it and replays the un-acked ledger with capped
    ///   exponential backoff + jitter;
    /// * restart/retry budget exhausted → the ledger is shed.
    pub fn submit_batch(
        &self,
        subs: &[Submission],
    ) -> Result<Vec<(usize, SubmitResult)>, String> {
        let n = self.slots.len();
        // (shard, shed-fast-path?) per submission, in submission order
        let mut routed: Vec<(usize, bool)> = Vec::with_capacity(subs.len());
        let mut per_shard: Vec<Vec<Submission>> = vec![Vec::new(); n];
        {
            let mut router = self.router.lock().unwrap();
            for sub in subs {
                let shard = router.route(sub);
                let shed = self
                    .shed_watermark
                    .is_some_and(|w| self.loads[shard].get() > w as i64);
                if shed {
                    self.metrics[shard].counter("jobs_shed").inc();
                } else {
                    per_shard[shard].push(*sub);
                }
                routed.push((shard, shed));
            }
        }
        // first attempt: record each shard's batch in its in-flight ledger
        // (under the slot lock, which we hold until its reply lands), then
        // send to every shard before awaiting any reply
        type Reply = mpsc::Receiver<Vec<SubmitResult>>;
        let mut pending: Vec<Option<(MutexGuard<'_, ShardSlot>, Option<Reply>)>> =
            Vec::with_capacity(n);
        for (shard, batch) in per_shard.into_iter().enumerate() {
            if batch.is_empty() {
                pending.push(None);
                continue;
            }
            let mut slot = self.slots[shard].lock().unwrap();
            debug_assert!(slot.ledger.is_empty(), "every exit path settles the ledger");
            slot.ledger = batch;
            let replay = slot.ledger.clone();
            let rx = slot.handle.as_ref().and_then(|h| h.send_batch(replay).ok());
            pending.push(Some((slot, rx)));
        }
        // collect: a failed send or dropped reply means the shard died —
        // hand the ledger to the supervisor
        let mut replies: Vec<std::vec::IntoIter<SubmitResult>> = Vec::with_capacity(n);
        for (shard, entry) in pending.into_iter().enumerate() {
            let Some((mut slot, rx)) = entry else {
                replies.push(Vec::new().into_iter());
                continue;
            };
            let results = match rx.and_then(|rx| rx.recv().ok()) {
                Some(results) => results,
                None => self.recover_and_replay(shard, &mut slot),
            };
            debug_assert_eq!(results.len(), slot.ledger.len());
            slot.ledger.clear();
            replies.push(results.into_iter());
        }
        Ok(routed
            .into_iter()
            .map(|(shard, shed)| {
                if shed {
                    (shard, SubmitResult::Shed)
                } else {
                    let r =
                        replies[shard].next().expect("per-shard reply count matches routing");
                    (shard, r)
                }
            })
            .collect())
    }

    /// The supervisor: shard `shard` is dead with `slot.ledger` un-acked.
    /// Respawn it (same partition, same derived seed, same registry and
    /// liveness flag) and replay the ledger, sleeping capped exponential
    /// backoff + jitter between attempts so a flapping shard isn't
    /// hammered.  Exhausting the restart or retry budget sheds the ledger
    /// with structured rejects — the caller always gets one verdict per
    /// submission.
    fn recover_and_replay(&self, shard: usize, slot: &mut ShardSlot) -> Vec<SubmitResult> {
        let mut backoff = self.retry_base;
        for _ in 0..=self.max_retries {
            if !self.shard_alive(shard) && !self.restart_shard(shard, slot) {
                break;
            }
            std::thread::sleep(backoff + self.jitter(backoff));
            backoff = (backoff * 2).min(self.retry_cap);
            let sent = slot.handle.as_ref().and_then(|h| h.send_batch(slot.ledger.clone()).ok());
            if let Some(results) = sent.and_then(|rx| rx.recv().ok()) {
                return results;
            }
        }
        let shed = self.metrics[shard].counter("jobs_shed");
        shed.add(slot.ledger.len() as u64);
        vec![SubmitResult::Shed; slot.ledger.len()]
    }

    /// Respawn shard `shard`'s master.  Reuses the slot's per-shard config
    /// (fresh seeded master), the shard's registry (counters and the
    /// router's load gauge survive), and the shared liveness flag (the
    /// router re-includes the shard the moment `spawn` marks it up).
    /// Returns false once the restart budget is exhausted or the spawn
    /// itself fails — the shard is then abandoned.
    fn restart_shard(&self, shard: usize, slot: &mut ShardSlot) -> bool {
        if slot.restarts >= self.max_restarts {
            return false;
        }
        slot.restarts += 1;
        // reap the corpse: join returns the panic as Err, which is expected
        if let Some(old) = slot.handle.take() {
            let _ = old.shutdown();
        }
        let mut master = Master::new(slot.cfg.clone());
        master.tick = self.tick;
        master.drain_slots = self.drain_slots;
        if let Some(bp) = self.backpressure {
            master.backpressure = bp;
        }
        master.metrics = self.metrics[shard].clone();
        master.alive = self.ups[shard].clone();
        master.machine_events = slot.events.clone();
        match master.spawn() {
            Ok(handle) => {
                self.metrics[shard].counter("master_restarts").inc();
                slot.handle = Some(handle);
                true
            }
            Err(_) => false,
        }
    }

    fn jitter(&self, backoff: Duration) -> Duration {
        let span = (backoff.as_micros() as u64 / 2).max(1);
        Duration::from_micros(self.jitter_rng.lock().unwrap().uniform_u64(0, span))
    }

    /// Put **every** shard into drain before joining any (so shards drain
    /// concurrently), then aggregate the per-shard reports and stop the
    /// sampler.  A shard that died and exhausted its budget contributes a
    /// tombstone report (`panicked: true`) synthesized from its registry
    /// instead of failing the whole shutdown.
    pub fn shutdown(self) -> Result<ServeReport, String> {
        let mut handles = Vec::with_capacity(self.slots.len());
        for slot in &self.slots {
            let mut slot = slot.lock().unwrap();
            if let Some(h) = &slot.handle {
                h.begin_shutdown();
            }
            handles.push((slot.handle.take(), slot.cfg.machines));
        }
        let mut reports = Vec::with_capacity(handles.len());
        for (shard, (handle, machines)) in handles.into_iter().enumerate() {
            let report = handle.and_then(|h| h.shutdown().ok());
            reports.push(report.unwrap_or_else(|| Report {
                completed: Vec::new(),
                rejected: self.metrics[shard].counter("jobs_rejected").get(),
                machines,
                slots: 0,
                slots_fired: 0,
                slots_skipped: 0,
                utilization: 0.0,
                streamed: None,
                panicked: true,
            }));
        }
        let series = self.sampler.map(|s| s.stop());
        Ok(ServeReport { shards: reports, series })
    }
}

/// Aggregate shutdown report: the per-shard [`Report`]s plus the sampled
/// metrics time series (when sampling was enabled).
#[derive(Debug)]
pub struct ServeReport {
    pub shards: Vec<Report>,
    pub series: Option<TimeSeries>,
}

impl ServeReport {
    /// Jobs completed across all shards — retained records plus any the
    /// capped (`max_resident_jobs`) masters drained into their sketches.
    pub fn completed(&self) -> usize {
        self.shards
            .iter()
            .map(|r| {
                r.completed.len() + r.streamed.as_ref().map_or(0, |s| s.drained as usize)
            })
            .sum()
    }

    pub fn rejected(&self) -> u64 {
        self.shards.iter().map(|r| r.rejected).sum()
    }

    /// Shards that died (and exhausted their restart budget) before they
    /// could drain — their reports are registry-derived tombstones.
    pub fn panicked(&self) -> usize {
        self.shards.iter().filter(|r| r.panicked).count()
    }

    pub fn slots(&self) -> u64 {
        self.shards.iter().map(|r| r.slots).sum()
    }

    /// Machine-weighted mean utilization across shards (each shard's
    /// utilization is already normalized by its own partition size).
    pub fn utilization(&self) -> f64 {
        let total: usize = self.shards.iter().map(|r| r.machines).sum();
        if total == 0 {
            return 0.0;
        }
        self.shards.iter().map(|r| r.utilization * r.machines as f64).sum::<f64>()
            / total as f64
    }

    /// Plain-text per-shard breakdown for the CLI.
    pub fn table(&self) -> String {
        let mut out = String::from("shard  machines  completed  rejected  utilization\n");
        for (i, r) in self.shards.iter().enumerate() {
            let done =
                r.completed.len() + r.streamed.as_ref().map_or(0, |s| s.drained as usize);
            out.push_str(&format!(
                "{i:>5}  {:>8}  {:>9}  {:>8}  {:>11.4}\n",
                r.machines, done, r.rejected, r.utilization
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scheduler::SchedulerKind;

    fn sub(num_tasks: u32, mean_duration: f64) -> Submission {
        Submission { num_tasks, mean_duration, alpha: 2.0 }
    }

    #[test]
    fn partition_spreads_remainder() {
        assert_eq!(partition_machines(10, 4), vec![3, 3, 2, 2]);
        assert_eq!(partition_machines(8, 2), vec![4, 4]);
        assert_eq!(partition_machines(7, 1), vec![7]);
        assert_eq!(partition_machines(5, 5), vec![1, 1, 1, 1, 1]);
        for (m, s) in [(1000, 3), (17, 4), (64, 5)] {
            let p = partition_machines(m, s);
            assert_eq!(p.iter().sum::<usize>(), m);
            assert!(p.iter().max().unwrap() - p.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    #[should_panic]
    fn partition_rejects_more_shards_than_machines() {
        partition_machines(2, 3);
    }

    fn loads(n: usize) -> Vec<Gauge> {
        let reg = MetricsRegistry::new();
        (0..n).map(|i| reg.gauge(&format!("q{i}"))).collect()
    }

    fn flags(n: usize) -> Vec<Arc<AtomicBool>> {
        (0..n).map(|_| Arc::new(AtomicBool::new(true))).collect()
    }

    #[test]
    fn hash_routing_is_deterministic_and_shape_keyed() {
        let mut r1 = ShardRouter::new(RoutePolicy::Hash, 7, loads(4), flags(4));
        let mut r2 = ShardRouter::new(RoutePolicy::Hash, 7, loads(4), flags(4));
        let s = sub(42, 2.5);
        let shard = r1.route(&s);
        for _ in 0..10 {
            assert_eq!(r1.route(&s), shard, "identical submissions pin one shard");
            assert_eq!(r2.route(&s), shard, "routing is stateless");
        }
        // different shapes spread: at least two distinct shards among many
        let mut seen = std::collections::BTreeSet::new();
        for t in 1..=64 {
            seen.insert(r1.route(&sub(t, 1.0)));
        }
        assert!(seen.len() > 1, "hash must not collapse to one shard");
    }

    #[test]
    fn single_shard_routes_to_zero() {
        let mut r = ShardRouter::new(RoutePolicy::P2c, 9, loads(1), flags(1));
        assert_eq!(r.route(&sub(3, 1.0)), 0);
    }

    #[test]
    fn hash_routing_probes_past_down_shards_and_reincludes() {
        let ups = flags(4);
        let mut r = ShardRouter::new(RoutePolicy::Hash, 7, loads(4), ups.clone());
        let mut baseline = Vec::new();
        for t in 1..=64 {
            baseline.push(r.route(&sub(t, 1.0)));
        }
        let down = baseline[0];
        ups[down].store(false, Ordering::Relaxed);
        for t in 1..=64 {
            assert_ne!(r.route(&sub(t, 1.0)), down, "down shard must be excluded");
        }
        ups[down].store(true, Ordering::Relaxed);
        let after: Vec<usize> = (1..=64).map(|t| r.route(&sub(t, 1.0))).collect();
        assert_eq!(after, baseline, "recovery restores the original picks");
    }

    #[test]
    fn p2c_routing_excludes_down_shard() {
        let ups = flags(2);
        ups[0].store(false, Ordering::Relaxed);
        let mut r = ShardRouter::new(RoutePolicy::P2c, 1, loads(2), ups);
        for t in 0u32..50 {
            assert_eq!(r.route(&sub(t + 1, 1.0)), 1, "lone survivor takes everything");
        }
    }

    #[test]
    fn all_down_falls_back_to_all_up_pick() {
        for policy in [RoutePolicy::Hash, RoutePolicy::P2c] {
            let ups = flags(3);
            for u in &ups {
                u.store(false, Ordering::Relaxed);
            }
            let mut r = ShardRouter::new(policy, 5, loads(3), ups);
            let shard = r.route(&sub(9, 2.0));
            assert!(shard < 3, "a restart target is still picked when every shard is down");
        }
    }

    #[test]
    fn p2c_prefers_less_loaded_shard() {
        let ls = loads(2);
        ls[0].set(1000);
        ls[1].set(0);
        let mut r = ShardRouter::new(RoutePolicy::P2c, 1, ls, flags(2));
        let mut counts = [0usize; 2];
        for t in 0u32..200 {
            counts[r.route(&sub(t % 7 + 1, 1.0))] += 1;
        }
        assert!(
            counts[1] > counts[0],
            "p2c must favor the unloaded shard: {counts:?}"
        );
        // shard 0 is still reachable (both draws landing on it)
        assert!(counts[0] > 0, "double-draw collisions keep the hot shard reachable");
    }

    #[test]
    fn serve_report_aggregates() {
        let mk = |machines: usize, rejected: u64, utilization: f64| Report {
            completed: Vec::new(),
            rejected,
            machines,
            slots: 10,
            slots_fired: 10,
            slots_skipped: 0,
            utilization,
            streamed: None,
            panicked: false,
        };
        let rep = ServeReport { shards: vec![mk(30, 2, 0.5), mk(10, 3, 0.9)], series: None };
        assert_eq!(rep.completed(), 0);
        assert_eq!(rep.rejected(), 5);
        assert_eq!(rep.slots(), 20);
        assert!((rep.utilization() - 0.6).abs() < 1e-12); // (30*0.5 + 10*0.9)/40
        assert!(rep.table().lines().count() == 3);
    }

    #[test]
    fn two_shards_complete_submissions() {
        let mut cfg = SimConfig::default();
        cfg.machines = 32;
        cfg.horizon = f64::INFINITY;
        cfg.use_runtime = false;
        cfg.scheduler = SchedulerKind::Sda;
        let mut sm = ShardedMaster::new(cfg, ServeConfig { shards: 2, ..Default::default() });
        sm.tick = Duration::from_micros(200);
        sm.sample_every = Some(Duration::from_secs(3600));
        let handle = sm.spawn().unwrap();
        assert_eq!(handle.shards(), 2);
        let subs: Vec<Submission> = (1..=10).map(|i| sub(i, 1.0)).collect();
        let results = handle.submit_batch(&subs).unwrap();
        assert_eq!(results.len(), 10);
        assert!(results.iter().all(|(_, r)| r.is_accepted()));
        let report = handle.shutdown().unwrap();
        assert_eq!(report.completed(), 10, "every accepted job drains somewhere");
        assert_eq!(report.shards.len(), 2);
        assert_eq!(report.shards.iter().map(|r| r.machines).sum::<usize>(), 32);
        let series = report.series.as_ref().unwrap();
        assert_eq!(series.len(), 2, "stop() samples each shard once");
        assert_eq!(
            series.aggregate_latest().counters.get("jobs_submitted"),
            Some(&10)
        );
    }
}
