//! The live master: a dedicated OS thread that owns the cluster + scheduler
//! and drives them in paced real time (one scheduling slot per tick),
//! accepting job submissions over a channel with watermark backpressure —
//! the deployable counterpart of the batch simulator.
//!
//! Python never appears here: SCA's P2 solve goes through the PJRT runtime
//! (or the rust fallback) exactly as in the batch path.

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread;
use std::time::{Duration, Instant};

use crate::cluster::job::JobId;
use crate::cluster::sim::{Cluster, SlotGate};
use crate::config::{SimConfig, WorkloadConfig};
use crate::metrics::{JobRecord, StreamedJobStats};
use crate::scheduler::{self, Scheduler};
use crate::workload::MachineEvent;

use super::backpressure::{Admission, Backpressure};
use super::metrics::{Counter, MetricsRegistry};

/// A live job submission.
#[derive(Clone, Copy, Debug)]
pub struct Submission {
    pub num_tasks: u32,
    pub mean_duration: f64,
    pub alpha: f64,
}

/// Reply to a submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitResult {
    Accepted { job: JobId, throttled: bool },
    Rejected,
    /// Structured load-shed from the sharded serve plane: the submission
    /// never reached a master — its routed shard was past the shed
    /// watermark, or every restart/retry of a dead shard was exhausted.
    /// A single [`Master`] never returns this.
    Shed,
}

impl SubmitResult {
    pub fn is_accepted(&self) -> bool {
        matches!(self, SubmitResult::Accepted { .. })
    }
}

enum Msg {
    Submit(Submission, mpsc::Sender<SubmitResult>),
    /// A submission burst: admitted in order, answered with one reply —
    /// the per-job channel round trip amortized across the whole batch.
    SubmitBatch(Vec<Submission>, mpsc::Sender<Vec<SubmitResult>>),
    /// Chaos hook: panic the master loop as if a real fault unwound it,
    /// exercising the sharded supervisor's restart path in tests and CI.
    Crash,
    Shutdown,
}

/// Final report when the master drains.
#[derive(Clone, Debug)]
pub struct Report {
    pub completed: Vec<JobRecord>,
    pub rejected: u64,
    /// Machines this master owned (a shard's partition size; see
    /// `coordinator::shard`).
    pub machines: usize,
    pub slots: u64,
    /// Slots whose `on_slot` actually ran vs. slots the demand-driven
    /// wakeup planner proved to be no-ops (`cfg.wakeup`; skipped slots
    /// still pace the loop and advance the clock, they just spend no CPU
    /// in the scheduler).
    pub slots_fired: u64,
    pub slots_skipped: u64,
    pub utilization: f64,
    /// Streaming aggregates when the master ran with
    /// `cfg.max_resident_jobs`: completed records were recycled into these
    /// sketches as they drained, so `completed` above stays empty and
    /// resident memory scales with the cap, not the submission volume.
    pub streamed: Option<StreamedJobStats>,
    /// True when this is a placeholder report synthesized by the sharded
    /// supervisor for a shard that died (and exhausted its restart budget)
    /// before it could drain: counters come from the shard's registry,
    /// per-job records are lost with the thread.
    pub panicked: bool,
}

/// Client handle: submit jobs, then shut down and collect the report.
pub struct MasterHandle {
    tx: mpsc::Sender<Msg>,
    join: thread::JoinHandle<Report>,
    alive: Arc<AtomicBool>,
}

impl MasterHandle {
    /// False once the master thread has exited for any reason — clean
    /// drain or panic unwind (a drop guard inside the thread flips the
    /// flag even when a panic skips every normal return path).
    pub fn is_alive(&self) -> bool {
        self.alive.load(Ordering::Relaxed)
    }

    /// Chaos hook: make the master loop panic as if a real fault killed
    /// it.  Asynchronous — poll [`is_alive`](Self::is_alive) to observe
    /// the death.  Errors only if the thread is already gone.
    pub fn inject_crash(&self) -> Result<(), String> {
        self.tx.send(Msg::Crash).map_err(|_| "master gone".to_string())
    }

    /// Submit a job; blocks until the master replies (sub-millisecond).
    pub fn submit(&self, sub: Submission) -> Result<SubmitResult, String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::Submit(sub, tx))
            .map_err(|_| "master gone".to_string())?;
        rx.recv().map_err(|_| "master dropped reply".to_string())
    }

    /// Submit a burst of jobs with one channel round trip; results come
    /// back in submission order.  Admission is identical to submitting the
    /// jobs one by one — batching changes wakeup cost, never decisions.
    pub fn submit_batch(&self, subs: Vec<Submission>) -> Result<Vec<SubmitResult>, String> {
        let rx = self.send_batch(subs)?;
        rx.recv().map_err(|_| "master dropped reply".to_string())
    }

    /// Send a burst without waiting for the reply; the returned channel
    /// yields the in-order results when the master drains the burst.  The
    /// sharded handle uses this to keep every shard admitting in parallel
    /// before collecting any replies.
    pub fn send_batch(
        &self,
        subs: Vec<Submission>,
    ) -> Result<mpsc::Receiver<Vec<SubmitResult>>, String> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Msg::SubmitBatch(subs, tx))
            .map_err(|_| "master gone".to_string())?;
        Ok(rx)
    }

    /// Start draining without joining, so a multi-shard shutdown can put
    /// every shard into drain before blocking on any of them.  A later
    /// `shutdown()` sends a second `Shutdown`, which the drained loop
    /// never reads — harmless.
    pub fn begin_shutdown(&self) {
        let _ = self.tx.send(Msg::Shutdown);
    }

    /// Stop accepting work, let the cluster drain, and return the report.
    pub fn shutdown(self) -> Result<Report, String> {
        let _ = self.tx.send(Msg::Shutdown);
        self.join.join().map_err(|_| "master panicked".to_string())
    }
}

/// The master configuration + spawner.
pub struct Master {
    cfg: SimConfig,
    /// Wall-clock duration of one scheduling slot.
    pub tick: Duration,
    /// Max slots to run after shutdown while draining in-flight jobs.
    pub drain_slots: u64,
    pub backpressure: Backpressure,
    pub metrics: MetricsRegistry,
    /// Liveness flag shared with the spawned thread: true while the loop
    /// runs, flipped false on any exit (drain or panic).  The sharded
    /// supervisor injects the *same* `Arc` across respawns so its router
    /// keeps one stable per-shard up/down view.
    pub alive: Arc<AtomicBool>,
    /// Scripted machine churn (`replay --machine-events`): staged into the
    /// cluster's event queue before the loop starts, on top of — or without
    /// — the stochastic `cfg.churn` process.  Machine ids are local to this
    /// master's partition.
    pub machine_events: Vec<MachineEvent>,
}

impl Master {
    pub fn new(cfg: SimConfig) -> Self {
        let backpressure = Backpressure::from_capacity(cfg.machines, 4.0, 16.0);
        Master {
            cfg,
            tick: Duration::from_millis(5),
            drain_slots: 5000,
            backpressure,
            metrics: MetricsRegistry::new(),
            alive: Arc::new(AtomicBool::new(true)),
            machine_events: Vec::new(),
        }
    }

    /// Spawn the master loop on its own thread; returns the handle.  The
    /// scheduler is constructed *inside* the thread (SCA's PJRT executor is
    /// thread-pinned).
    ///
    /// The loop body runs under `catch_unwind`: a panic increments the
    /// registry's `master_panics` counter and drops `alive` to false (the
    /// supervisor's death signal) before the payload is rethrown, so
    /// `shutdown()` on a crashed master still reports "master panicked".
    pub fn spawn(self) -> Result<MasterHandle, String> {
        // validate the scheduler config up-front so spawn fails loudly
        scheduler::build(&self.cfg, &WorkloadConfig::paper(1.0))?;
        let (tx, rx) = mpsc::channel();
        let alive = self.alive.clone();
        alive.store(true, Ordering::Relaxed);
        let thread_alive = alive.clone();
        let panics = self.metrics.counter("master_panics");
        let join = thread::Builder::new()
            .name("specsim-master".into())
            .spawn(move || {
                // drop guard: flips liveness on ANY exit, unwind included
                struct AliveGuard(Arc<AtomicBool>);
                impl Drop for AliveGuard {
                    fn drop(&mut self) {
                        self.0.store(false, Ordering::Relaxed);
                    }
                }
                let _guard = AliveGuard(thread_alive);
                let result = std::panic::catch_unwind(AssertUnwindSafe(move || {
                    let sched = scheduler::build(&self.cfg, &WorkloadConfig::paper(1.0))
                        .expect("scheduler build validated before spawn");
                    run_loop(self, sched, rx)
                }));
                match result {
                    Ok(report) => report,
                    Err(payload) => {
                        panics.inc();
                        std::panic::resume_unwind(payload)
                    }
                }
            })
            .map_err(|e| e.to_string())?;
        Ok(MasterHandle { tx, join, alive })
    }
}

/// Admit one submission against the watermarks; shared by the single and
/// batched message arms so batching can never change a decision.
fn admit_one(
    cluster: &mut Cluster,
    bp: &Backpressure,
    jobs_in: &Counter,
    jobs_rejected: &Counter,
    sub: &Submission,
) -> SubmitResult {
    let admission = bp.admit(cluster.queued_tasks(), sub.num_tasks as usize);
    if admission == Admission::Reject {
        jobs_rejected.inc();
        SubmitResult::Rejected
    } else {
        jobs_in.inc();
        let job = cluster.add_job(sub.mean_duration, sub.alpha, sub.num_tasks);
        SubmitResult::Accepted { job, throttled: admission == Admission::Throttle }
    }
}

fn handle_msg(
    msg: Msg,
    cluster: &mut Cluster,
    bp: &Backpressure,
    jobs_in: &Counter,
    jobs_rejected: &Counter,
    draining: &mut bool,
) {
    match msg {
        Msg::Submit(sub, reply) => {
            let result = admit_one(cluster, bp, jobs_in, jobs_rejected, &sub);
            let _ = reply.send(result);
        }
        Msg::SubmitBatch(subs, reply) => {
            // preallocated ticket buffer: one reply send for the burst
            let mut results = Vec::with_capacity(subs.len());
            for sub in &subs {
                results.push(admit_one(cluster, bp, jobs_in, jobs_rejected, sub));
            }
            let _ = reply.send(results);
        }
        Msg::Crash => panic!("injected master crash (chaos hook)"),
        Msg::Shutdown => *draining = true,
    }
}

fn run_loop(master: Master, mut sched: Box<dyn Scheduler>, rx: mpsc::Receiver<Msg>) -> Report {
    let slot_dt = master.cfg.slot_dt;
    let bp = master.backpressure;
    let mut gate = SlotGate::new(master.cfg.wakeup);
    let mut sink = master.cfg.max_resident_jobs.map(|_| StreamedJobStats::new());
    let mut cluster = Cluster::new_live(master.cfg);
    // stage the scripted churn schedule (replay --machine-events) before
    // the first slot: the events sit in the queue like stochastic churn
    for ev in &master.machine_events {
        cluster.inject_machine_event(ev.time, ev.machine, ev.fail);
    }
    let metrics = master.metrics.clone();
    let jobs_in = metrics.counter("jobs_submitted");
    let jobs_done = metrics.counter("jobs_completed");
    // the registry outlives a supervisor respawn: completions counted by a
    // previous incarnation stay in the counter, ours add on top
    let done_base = jobs_done.get();
    let jobs_rejected = metrics.counter("jobs_rejected");
    let q_depth = metrics.gauge("queued_tasks");
    let busy = metrics.gauge("busy_machines");
    let evq_depth = metrics.gauge("event_queue_len");
    let mut slots: u64 = 0;
    let mut draining = false;
    let mut drain_left = master.drain_slots;
    let mut next_tick = Instant::now() + master.tick;
    loop {
        // serve submissions until the next slot boundary
        while !draining {
            let now = Instant::now();
            if now >= next_tick {
                break;
            }
            match rx.recv_timeout(next_tick - now) {
                Ok(msg) => {
                    handle_msg(msg, &mut cluster, &bp, &jobs_in, &jobs_rejected, &mut draining)
                }
                Err(mpsc::RecvTimeoutError::Timeout) => break,
                Err(mpsc::RecvTimeoutError::Disconnected) => draining = true,
            }
            // burst drain: everything already queued rides the same wakeup,
            // re-checking the slot boundary so a flood can't starve the
            // scheduler of its tick
            while !draining && Instant::now() < next_tick {
                match rx.try_recv() {
                    Ok(msg) => handle_msg(
                        msg,
                        &mut cluster,
                        &bp,
                        &jobs_in,
                        &jobs_rejected,
                        &mut draining,
                    ),
                    Err(mpsc::TryRecvError::Empty) => break,
                    Err(mpsc::TryRecvError::Disconnected) => draining = true,
                }
            }
        }
        // slot boundary: events first (a slot observes its instant fully),
        // then the wakeup planner decides whether the scheduler must run
        // at all — a quiet slot costs a flag check, not a pipeline pass
        next_tick += master.tick;
        let now = cluster.clock + slot_dt;
        cluster.advance_to(now, sched.as_mut());
        gate.slot(&mut cluster, sched.as_mut(), now);
        slots += 1;
        if let Some(sink) = &mut sink {
            cluster.drain_completed_into(sink);
        }
        // completion gauge counts drained + resident so capped recycling
        // never walks it backwards
        let done_total =
            done_base + sink.as_ref().map_or(0, |s| s.drained) + cluster.completed.len() as u64;
        jobs_done.add(done_total.saturating_sub(jobs_done.get()));
        // O(1) reads: queued_tasks comes off the SchedIndex counter, and
        // stale-entry compaction keeps the event heap tracking live copies
        q_depth.set(cluster.queued_tasks() as i64);
        busy.set(cluster.machines.busy_count() as i64);
        evq_depth.set(cluster.events.len() as i64);
        if draining {
            let drained = cluster.running.is_empty() && cluster.queued.is_empty();
            if drained || drain_left == 0 {
                let streamed = sink.map(|mut s| {
                    // final drain: sketch the records still resident so
                    // capped aggregates cover every completed job
                    for r in cluster.completed.drain(..) {
                        s.absorb(&r);
                    }
                    s
                });
                return Report {
                    utilization: cluster.total_machine_time
                        / (cluster.machines.total() as f64 * cluster.clock.max(1e-9)),
                    machines: cluster.machines.total(),
                    completed: std::mem::take(&mut cluster.completed),
                    rejected: jobs_rejected.get(),
                    slots,
                    slots_fired: gate.fired,
                    slots_skipped: gate.skipped,
                    streamed,
                    panicked: false,
                };
            }
            drain_left -= 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(machines: usize) -> SimConfig {
        let mut c = SimConfig::default();
        c.machines = machines;
        c.horizon = f64::INFINITY;
        c.use_runtime = false;
        c.scheduler = crate::scheduler::SchedulerKind::Sda;
        c
    }

    #[test]
    fn submits_complete_and_drain() {
        let mut master = Master::new(cfg(64));
        master.tick = Duration::from_micros(200);
        let metrics = master.metrics.clone();
        let handle = master.spawn().unwrap();
        for _ in 0..20 {
            let r = handle
                .submit(Submission { num_tasks: 5, mean_duration: 1.0, alpha: 2.0 })
                .unwrap();
            assert!(r.is_accepted());
        }
        let report = handle.shutdown().unwrap();
        assert_eq!(report.completed.len(), 20, "all jobs drain");
        assert_eq!(report.rejected, 0);
        assert!(report.utilization > 0.0);
        assert_eq!(report.slots_fired + report.slots_skipped, report.slots);
        assert!(report.slots_fired > 0, "scheduling must have happened");
        assert!(
            report.slots_skipped > 0,
            "slots spent waiting on heavy-tail stragglers should be provable no-ops"
        );
        assert_eq!(metrics.counter("jobs_submitted").get(), 20);
        for r in &report.completed {
            assert!(r.flowtime > 0.0);
        }
    }

    #[test]
    fn batch_admission_matches_sequential() {
        // hour-long tick: no slot boundary fires while submitting, so the
        // queue never drains mid-sequence and admission is a pure function
        // of the submission order — batched and sequential must agree
        let subs: Vec<Submission> = (0..40)
            .map(|_| Submission { num_tasks: 4, mean_duration: 5.0, alpha: 2.0 })
            .collect();
        let run = |batched: bool| -> Vec<bool> {
            let mut master = Master::new(cfg(4));
            master.tick = Duration::from_secs(3600);
            master.drain_slots = 50;
            master.backpressure = Backpressure::new(8, 16);
            let handle = master.spawn().unwrap();
            let results: Vec<SubmitResult> = if batched {
                handle.submit_batch(subs.clone()).unwrap()
            } else {
                subs.iter().map(|s| handle.submit(*s).unwrap()).collect()
            };
            let _ = handle.shutdown();
            results.iter().map(|r| r.is_accepted()).collect()
        };
        let sequential = run(false);
        let batch = run(true);
        assert_eq!(sequential, batch, "batching must not change admission decisions");
        let accepted = batch.iter().filter(|&&a| a).count();
        assert_eq!(accepted, 4, "4 jobs x 4 tasks reach high watermark 16, rest reject");
    }

    #[test]
    fn capped_master_streams_completions_into_sketches() {
        let mut c = cfg(64);
        c.max_resident_jobs = Some(4);
        let mut master = Master::new(c);
        master.tick = Duration::from_micros(200);
        let metrics = master.metrics.clone();
        let handle = master.spawn().unwrap();
        for _ in 0..20 {
            let r = handle
                .submit(Submission { num_tasks: 5, mean_duration: 1.0, alpha: 2.0 })
                .unwrap();
            assert!(r.is_accepted());
        }
        let report = handle.shutdown().unwrap();
        let s = report.streamed.as_ref().expect("capped run reports sketches");
        assert_eq!(s.drained, 20, "every completion lands in the sketches");
        assert!(report.completed.is_empty(), "records recycled, not retained");
        assert!(s.flowtime.mean() > 0.0);
        assert_eq!(metrics.counter("jobs_completed").get(), 20);
    }

    #[test]
    fn report_records_partition_size() {
        let mut master = Master::new(cfg(8));
        master.tick = Duration::from_micros(200);
        let handle = master.spawn().unwrap();
        let report = handle.shutdown().unwrap();
        assert_eq!(report.machines, 8);
    }

    #[test]
    fn injected_crash_flips_liveness_and_counts() {
        let mut master = Master::new(cfg(4));
        master.tick = Duration::from_micros(200);
        let metrics = master.metrics.clone();
        let handle = master.spawn().unwrap();
        assert!(handle.is_alive());
        handle.inject_crash().unwrap();
        // the crash is asynchronous: wait for the drop guard to fire
        let deadline = Instant::now() + Duration::from_secs(10);
        while handle.is_alive() && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(1));
        }
        assert!(!handle.is_alive(), "drop guard must flip liveness on unwind");
        assert_eq!(metrics.counter("master_panics").get(), 1);
        assert!(handle.shutdown().is_err(), "join on a crashed master reports the panic");
    }

    #[test]
    fn backpressure_rejects_floods() {
        let mut master = Master::new(cfg(4));
        master.tick = Duration::from_millis(50); // slow slots: queue builds up
        master.backpressure = Backpressure::new(8, 16);
        let handle = master.spawn().unwrap();
        let mut rejected = 0;
        for _ in 0..40 {
            match handle
                .submit(Submission { num_tasks: 4, mean_duration: 5.0, alpha: 2.0 })
                .unwrap()
            {
                SubmitResult::Rejected => rejected += 1,
                _ => {}
            }
        }
        assert!(rejected > 0, "flood must trip the high watermark");
        let _ = handle.shutdown();
    }
}
