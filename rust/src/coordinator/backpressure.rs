//! Admission control for the live master: bound the queued backlog so a
//! heavily loaded cluster sheds load at the front door instead of growing
//! an unbounded queue (the streaming-orchestrator counterpart of the
//! paper's "heavily loaded regime").

/// Admission decision for one submission.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    Admit,
    /// Over the high watermark: reject outright.
    Reject,
    /// Between watermarks: admit but signal the client to slow down.
    Throttle,
}

/// Watermark-based backpressure on queued *tasks* (not jobs: a single
/// 100-task job is 100 machines of demand).
#[derive(Clone, Copy, Debug)]
pub struct Backpressure {
    /// Start throttling above this many queued tasks.
    pub low_watermark: usize,
    /// Reject above this many queued tasks.
    pub high_watermark: usize,
}

impl Backpressure {
    pub fn new(low_watermark: usize, high_watermark: usize) -> Self {
        assert!(low_watermark <= high_watermark);
        Backpressure { low_watermark, high_watermark }
    }

    /// Size the watermarks from cluster capacity: low = `low_slots` x M,
    /// high = `high_slots` x M.
    pub fn from_capacity(machines: usize, low_slots: f64, high_slots: f64) -> Self {
        Backpressure::new(
            (machines as f64 * low_slots) as usize,
            (machines as f64 * high_slots) as usize,
        )
    }

    pub fn admit(&self, queued_tasks: usize, incoming_tasks: usize) -> Admission {
        let after = queued_tasks + incoming_tasks;
        if after > self.high_watermark {
            Admission::Reject
        } else if after > self.low_watermark {
            Admission::Throttle
        } else {
            Admission::Admit
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watermark_bands() {
        let bp = Backpressure::new(10, 20);
        assert_eq!(bp.admit(0, 5), Admission::Admit);
        assert_eq!(bp.admit(8, 5), Admission::Throttle);
        assert_eq!(bp.admit(18, 5), Admission::Reject);
        assert_eq!(bp.admit(10, 0), Admission::Admit); // boundary inclusive
    }

    #[test]
    fn from_capacity() {
        let bp = Backpressure::from_capacity(100, 2.0, 5.0);
        assert_eq!(bp.low_watermark, 200);
        assert_eq!(bp.high_watermark, 500);
    }

    #[test]
    #[should_panic]
    fn inverted_watermarks_panic() {
        Backpressure::new(10, 5);
    }
}
