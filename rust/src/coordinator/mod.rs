//! Async streaming coordinator (the deployable L3 front-end): a tokio-based
//! master that accepts live job submissions, applies admission control, and
//! drives the same scheduler/cluster machinery the simulator exercises.

pub mod backpressure;
pub mod master;
pub mod metrics;
pub mod router;

pub use backpressure::Backpressure;
pub use master::{Master, MasterHandle, Submission};
pub use metrics::MetricsRegistry;
pub use router::Router;
