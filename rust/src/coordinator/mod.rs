//! Streaming coordinator (the deployable L3 front-end): thread-per-shard
//! live masters that accept job submissions over channels, apply admission
//! control, and drive the same scheduler/cluster machinery the simulator
//! exercises — single-master (`master`) or N-shard (`shard`) deployments.

pub mod backpressure;
pub mod master;
pub mod metrics;
pub mod router;
pub mod shard;

pub use backpressure::Backpressure;
pub use master::{Master, MasterHandle, Submission};
pub use metrics::MetricsRegistry;
pub use router::Router;
pub use shard::{ServeReport, ShardRouter, ShardedHandle, ShardedMaster};
