//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from the rust hot path.
//! Python runs only at build time (`make artifacts`); after that the binary
//! is self-contained.

pub mod artifacts;
pub mod pjrt;
pub mod solver;

pub use artifacts::Manifest;
pub use pjrt::PjrtExecutor;
pub use solver::PjrtP2;
