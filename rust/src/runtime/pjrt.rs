//! Thin wrapper over the `xla` crate: one shared CPU PJRT client, one
//! compiled executable per artifact, f32-tensor in / f32-tensor out.
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see python/compile/aot.py).

use std::cell::RefCell;
use std::path::Path;

thread_local! {
    // One PJRT CPU client per thread: the xla crate's client is Rc-based
    // (not Send/Sync), and each executable stays on the thread that
    // compiled it.  Every thread that touches the runtime pays the client
    // construction once.
    static CLIENT: RefCell<Option<Result<xla::PjRtClient, String>>> = const { RefCell::new(None) };
}

fn with_client<T>(f: impl FnOnce(&xla::PjRtClient) -> Result<T, String>) -> Result<T, String> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        let client = slot
            .get_or_insert_with(|| xla::PjRtClient::cpu().map_err(|e| e.to_string()));
        match client {
            Ok(c) => f(c),
            Err(e) => Err(e.clone()),
        }
    })
}

/// A compiled HLO module plus its I/O shapes.
pub struct PjrtExecutor {
    exe: xla::PjRtLoadedExecutable,
    /// Input shapes (row-major dims) in argument order.
    pub input_shapes: Vec<Vec<usize>>,
    /// Output shapes in tuple order.
    pub output_shapes: Vec<Vec<usize>>,
}

impl PjrtExecutor {
    /// Load + compile an HLO text file.
    pub fn load(
        path: impl AsRef<Path>,
        input_shapes: Vec<Vec<usize>>,
        output_shapes: Vec<Vec<usize>>,
    ) -> Result<Self, String> {
        let proto = xla::HloModuleProto::from_text_file(path.as_ref())
            .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = with_client(|client| client.compile(&comp).map_err(|e| e.to_string()))?;
        Ok(PjrtExecutor { exe, input_shapes, output_shapes })
    }

    /// Execute with flat f32 buffers (one per input, row-major).  Returns
    /// one flat f32 buffer per tuple output.
    pub fn run(&self, inputs: &[Vec<f32>]) -> Result<Vec<Vec<f32>>, String> {
        if inputs.len() != self.input_shapes.len() {
            return Err(format!(
                "expected {} inputs, got {}",
                self.input_shapes.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&self.input_shapes) {
            let n: usize = shape.iter().product::<usize>().max(1);
            if buf.len() != n {
                return Err(format!("input length {} != shape {:?}", buf.len(), shape));
            }
            let lit = xla::Literal::vec1(buf);
            let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
            let lit = lit.reshape(&dims).map_err(|e| e.to_string())?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| e.to_string())?[0][0]
            .to_literal_sync()
            .map_err(|e| e.to_string())?;
        // jax lowers with return_tuple=True: the root is always a tuple
        let parts = result.to_tuple().map_err(|e| e.to_string())?;
        if parts.len() != self.output_shapes.len() {
            return Err(format!(
                "expected {} outputs, got {}",
                self.output_shapes.len(),
                parts.len()
            ));
        }
        parts
            .into_iter()
            .map(|p| p.to_vec::<f32>().map_err(|e| e.to_string()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // Executor integration tests live in rust/tests/runtime_integration.rs
    // (they need `make artifacts` to have run).  Here we only check error
    // paths that need no artifacts.
    use super::*;

    #[test]
    fn missing_file_errors() {
        let r = PjrtExecutor::load("/no/such/file.hlo.txt", vec![], vec![]);
        assert!(r.is_err());
    }
}
