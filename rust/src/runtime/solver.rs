//! The P2 backend that runs the AOT-compiled JAX/Pallas solver on the SCA
//! hot path, plus loaders for the analysis artifacts (sigma curve, SDA
//! tables) used by the figure harness.

use crate::opt::gradient::P2Problem;
use crate::scheduler::budget::P2Backend;

use super::artifacts::Manifest;
use super::pjrt::PjrtExecutor;

/// PJRT-backed P2 solver (artifact `p2_solver`).
pub struct PjrtP2 {
    exec: PjrtExecutor,
    batch: usize,
    /// Executions performed (diagnostics/benching).
    pub calls: u64,
}

impl PjrtP2 {
    pub fn load(artifacts_dir: &str) -> Result<Self, String> {
        let manifest = Manifest::load(artifacts_dir)?;
        let batch = manifest.statics.batch;
        let entry = manifest
            .entry("p2_solver")
            .ok_or("p2_solver not in manifest")?;
        let exec = PjrtExecutor::load(
            manifest.hlo_path("p2_solver")?,
            entry.inputs.iter().map(|t| t.shape.clone()).collect(),
            entry.outputs.iter().map(|t| t.shape.clone()).collect(),
        )?;
        Ok(PjrtP2 { exec, batch, calls: 0 })
    }
}

impl P2Backend for PjrtP2 {
    fn backend_name(&self) -> &'static str {
        "pjrt"
    }

    fn max_batch(&self) -> usize {
        self.batch
    }

    fn solve(&mut self, p: &P2Problem) -> Vec<f64> {
        let b = self.batch;
        assert!(p.jobs.len() <= b, "batch overflow: {} > {b}", p.jobs.len());
        let mut mu = vec![0.0f32; b];
        let mut m = vec![0.0f32; b];
        let mut age = vec![0.0f32; b];
        let mut mask = vec![0.0f32; b];
        for (i, j) in p.jobs.iter().enumerate() {
            mu[i] = j.mu as f32;
            m[i] = j.m as f32;
            age[i] = j.age as f32;
            mask[i] = 1.0;
        }
        let params = vec![
            p.n_avail as f32,
            p.gamma as f32,
            p.r as f32,
            p.alpha as f32,
        ];
        match self.exec.run(&[mu, m, age, mask, params]) {
            Ok(outs) => {
                self.calls += 1;
                outs[0][..p.jobs.len()].iter().map(|&c| c as f64).collect()
            }
            Err(e) => {
                // never take the cluster down over a solver hiccup: degrade
                // to no cloning for this slot
                eprintln!("pjrt p2 solve failed ({e}); degrading to c = 1");
                vec![1.0; p.jobs.len()]
            }
        }
    }
}

/// The Fig. 4 sigma curve from the `sigma_curve` artifact:
/// returns (sigma_grid, `E[R]/E[x]`).
pub fn sigma_curve(artifacts_dir: &str, alpha: f64) -> Result<(Vec<f64>, Vec<f64>), String> {
    let manifest = Manifest::load(artifacts_dir)?;
    let entry = manifest
        .entry("sigma_curve")
        .ok_or("sigma_curve not in manifest")?;
    let exec = PjrtExecutor::load(
        manifest.hlo_path("sigma_curve")?,
        entry.inputs.iter().map(|t| t.shape.clone()).collect(),
        entry.outputs.iter().map(|t| t.shape.clone()).collect(),
    )?;
    let outs = exec.run(&[vec![alpha as f32]])?;
    Ok((
        outs[0].iter().map(|&x| x as f64).collect(),
        outs[1].iter().map(|&x| x as f64).collect(),
    ))
}

/// The SDA tables from the `sda_opt` artifact: (`tau[S][C]`, `resource[S][C]`)
/// flattened row-major plus the sigma grid from the manifest statics.
pub fn sda_tables(
    artifacts_dir: &str,
    alpha: f64,
    s: f64,
) -> Result<(Vec<f64>, Vec<f64>, Vec<f64>, usize), String> {
    let manifest = Manifest::load(artifacts_dir)?;
    let entry = manifest.entry("sda_opt").ok_or("sda_opt not in manifest")?;
    let exec = PjrtExecutor::load(
        manifest.hlo_path("sda_opt")?,
        entry.inputs.iter().map(|t| t.shape.clone()).collect(),
        entry.outputs.iter().map(|t| t.shape.clone()).collect(),
    )?;
    let outs = exec.run(&[vec![alpha as f32, s as f32]])?;
    let sigma = manifest.statics.sigma_grid.values();
    let c_max = manifest.statics.sda_c_max;
    Ok((
        sigma,
        outs[0].iter().map(|&x| x as f64).collect(),
        outs[1].iter().map(|&x| x as f64).collect(),
        c_max,
    ))
}
