//! Artifact discovery: parse `artifacts/manifest.json` (written by
//! `python/compile/aot.py`) so the runtime knows the exact shapes and the
//! static grids baked into each HLO module.

use std::collections::HashMap;
use std::fs;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub file: String,
}

#[derive(Clone, Copy, Debug)]
pub struct GridSpec {
    pub lo: f64,
    pub hi: f64,
    pub n: usize,
}

impl GridSpec {
    /// Materialize the (linear) grid.
    pub fn values(&self) -> Vec<f64> {
        (0..self.n)
            .map(|i| self.lo + (self.hi - self.lo) * i as f64 / (self.n - 1) as f64)
            .collect()
    }

    fn from_json(j: &Json, what: &str) -> Result<Self, String> {
        Ok(GridSpec {
            lo: j.get("lo").and_then(Json::as_f64).ok_or(format!("{what}.lo"))?,
            hi: j.get("hi").and_then(Json::as_f64).ok_or(format!("{what}.hi"))?,
            n: j.get("n").and_then(Json::as_usize).ok_or(format!("{what}.n"))?,
        })
    }
}

#[derive(Clone, Debug)]
pub struct Statics {
    pub batch: usize,
    pub c_grid: GridSpec,
    pub sigma_grid: GridSpec,
    pub sda_c_max: usize,
    pub p2_iters: usize,
    pub etas: Vec<f64>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub statics: Statics,
    pub artifacts: HashMap<String, ArtifactEntry>,
    dir: PathBuf,
}

fn tensor_specs(j: &Json, what: &str) -> Result<Vec<TensorSpec>, String> {
    j.as_arr()
        .ok_or(format!("{what}: array"))?
        .iter()
        .map(|t| {
            Ok(TensorSpec {
                name: t
                    .get("name")
                    .and_then(Json::as_str)
                    .ok_or(format!("{what}.name"))?
                    .to_string(),
                shape: t
                    .get("shape")
                    .and_then(Json::as_arr)
                    .ok_or(format!("{what}.shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or(format!("{what}.shape: int")))
                    .collect::<Result<_, _>>()?,
            })
        })
        .collect()
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self, String> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = fs::read_to_string(&path)
            .map_err(|e| format!("{}: {e} (run `make artifacts`)", path.display()))?;
        let j = Json::parse(&text)?;
        let s = j.get("statics").ok_or("manifest: statics")?;
        let statics = Statics {
            batch: s.get("batch").and_then(Json::as_usize).ok_or("statics.batch")?,
            c_grid: GridSpec::from_json(s.get("c_grid").ok_or("statics.c_grid")?, "c_grid")?,
            sigma_grid: GridSpec::from_json(
                s.get("sigma_grid").ok_or("statics.sigma_grid")?,
                "sigma_grid",
            )?,
            sda_c_max: s
                .get("sda_c_max")
                .and_then(Json::as_usize)
                .ok_or("statics.sda_c_max")?,
            p2_iters: s
                .get("p2_iters")
                .and_then(Json::as_usize)
                .ok_or("statics.p2_iters")?,
            etas: s
                .get("etas")
                .and_then(Json::as_arr)
                .ok_or("statics.etas")?
                .iter()
                .map(|e| e.as_f64().ok_or("statics.etas: num".to_string()))
                .collect::<Result<_, _>>()?,
        };
        let mut artifacts = HashMap::new();
        for (name, entry) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or("manifest: artifacts")?
        {
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    inputs: tensor_specs(entry.get("inputs").ok_or("inputs")?, "inputs")?,
                    outputs: tensor_specs(entry.get("outputs").ok_or("outputs")?, "outputs")?,
                    file: entry
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or("file")?
                        .to_string(),
                },
            );
        }
        Ok(Manifest { statics, artifacts, dir })
    }

    /// Absolute path of a named artifact's HLO text.
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf, String> {
        let entry = self
            .artifacts
            .get(name)
            .ok_or_else(|| format!("artifact '{name}' not in manifest"))?;
        let p = self.dir.join(&entry.file);
        if !p.exists() {
            return Err(format!("{} missing (run `make artifacts`)", p.display()));
        }
        Ok(p)
    }

    pub fn entry(&self, name: &str) -> Option<&ArtifactEntry> {
        self.artifacts.get(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_fixture(dir: &Path) {
        fs::create_dir_all(dir).unwrap();
        let manifest = r#"{
            "statics": {
                "batch": 64,
                "c_grid": {"lo": 1.0, "hi": 16.0, "n": 64},
                "sigma_grid": {"lo": 0.05, "hi": 6.0, "n": 128},
                "sda_c_max": 8,
                "p2_iters": 400,
                "etas": [0.2, 0.3, 0.4]
            },
            "artifacts": {
                "p2_solver": {
                    "inputs": [{"name": "mu", "shape": [64]}],
                    "outputs": [{"name": "c_star", "shape": [64]}],
                    "file": "p2_solver.hlo.txt"
                }
            }
        }"#;
        fs::write(dir.join("manifest.json"), manifest).unwrap();
        fs::write(dir.join("p2_solver.hlo.txt"), "HloModule fake").unwrap();
    }

    #[test]
    fn loads_fixture() {
        let dir = std::env::temp_dir().join("specsim_manifest_test");
        write_fixture(&dir);
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.statics.batch, 64);
        assert_eq!(m.statics.c_grid.values().len(), 64);
        assert!((m.statics.c_grid.values()[0] - 1.0).abs() < 1e-12);
        assert_eq!(m.statics.etas, vec![0.2, 0.3, 0.4]);
        let e = m.entry("p2_solver").unwrap();
        assert_eq!(e.inputs[0].shape, vec![64]);
        assert!(m.hlo_path("p2_solver").is_ok());
        assert!(m.hlo_path("nope").is_err());
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load("/definitely/not/here").is_err());
    }

    #[test]
    fn grid_values_endpoints() {
        let g = GridSpec { lo: 1.0, hi: 16.0, n: 64 };
        let v = g.values();
        assert!((v[0] - 1.0).abs() < 1e-12);
        assert!((v[63] - 16.0).abs() < 1e-12);
        assert!(v.windows(2).all(|w| w[1] > w[0]));
    }
}
