//! Report writers: pretty summary tables for the terminal and CSV series
//! for the per-figure output files under `results/`.

use std::fmt::Write as _;
use std::fs;
use std::path::Path;

use crate::cluster::sim::SimResult;
use crate::experiment::SweepResult;
use crate::stats::Cdf;

/// Headline comparison row for one scheduler run.
#[derive(Clone, Debug)]
pub struct SummaryRow {
    /// Policy label (canonical name or composition spec).
    pub scheduler: String,
    pub jobs: usize,
    pub mean_flowtime: f64,
    pub p80_flowtime: f64,
    pub p90_flowtime: f64,
    pub mean_resource: f64,
    pub p80_resource: f64,
    pub mean_net_utility: f64,
    pub utilization: f64,
    pub speculative_launches: u64,
}

impl SummaryRow {
    pub fn from_result(res: &SimResult) -> Self {
        // A capped run (`max_resident_jobs`) drains completed records into
        // streaming sketches instead of retaining them; report from those.
        // Below the P² warm-up (5 samples) the sketch interpolates exactly
        // like `Cdf::quantile`, so tiny capped runs still match.
        if let Some(s) = &res.streamed {
            return SummaryRow {
                scheduler: res.scheduler.clone(),
                jobs: s.drained as usize,
                mean_flowtime: s.flowtime.mean(),
                p80_flowtime: s.flow_p80.quantile(),
                p90_flowtime: s.flow_p90.quantile(),
                mean_resource: s.resource.mean(),
                p80_resource: s.res_p80.quantile(),
                mean_net_utility: s.net_utility.mean(),
                utilization: res.utilization,
                speculative_launches: res.speculative_launches,
            };
        }
        let mut ft = res.flowtime_cdf();
        let mut rs = res.resource_cdf();
        SummaryRow {
            scheduler: res.scheduler.clone(),
            jobs: res.completed.len(),
            mean_flowtime: ft.mean(),
            p80_flowtime: ft.quantile(0.8),
            p90_flowtime: ft.quantile(0.9),
            mean_resource: rs.mean(),
            p80_resource: rs.quantile(0.8),
            mean_net_utility: res.mean_net_utility(),
            utilization: res.utilization,
            speculative_launches: res.speculative_launches,
        }
    }
}

/// Render rows as an aligned terminal table (paper-style comparison).
pub fn summary_table(rows: &[SummaryRow]) -> String {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "{:<10} {:>7} {:>9} {:>8} {:>8} {:>9} {:>8} {:>10} {:>6} {:>8}",
        "scheduler",
        "jobs",
        "mean_ft",
        "p80_ft",
        "p90_ft",
        "mean_res",
        "p80_res",
        "net_util",
        "util",
        "backups"
    );
    for r in rows {
        let _ = writeln!(
            out,
            "{:<10} {:>7} {:>9.3} {:>8.2} {:>8.2} {:>9.3} {:>8.2} {:>10.3} {:>6.3} {:>8}",
            r.scheduler,
            r.jobs,
            r.mean_flowtime,
            r.p80_flowtime,
            r.p90_flowtime,
            r.mean_resource,
            r.p80_resource,
            r.mean_net_utility,
            r.utilization,
            r.speculative_launches
        );
    }
    out
}

/// CSV with one CMF series per labelled sample set (the paper's Fig. 2/6
/// panels).  Columns: label,x,cmf.
pub fn cmf_csv(series: &mut [(&str, Cdf)], points: usize) -> String {
    let mut out = String::from("label,x,cmf\n");
    for (label, cdf) in series.iter_mut() {
        for (x, f) in cdf.cmf_series(points) {
            let _ = writeln!(out, "{label},{x},{f}");
        }
    }
    out
}

/// Serialize a sweep's full grid, one row per (policy, load, seed) cell.
/// The row order is fixed by the spec (policy-major), so the same spec
/// always produces the identical file regardless of worker count.
///
/// The churn columns (`machines_failed,copies_lost,work_lost`) are
/// appended **only when the sweep's base config has churn enabled** — a
/// zero-churn sweep serializes byte-identically to the pre-churn format,
/// which is what pins the canonical snapshot.
pub fn sweep_csv(sweep: &SweepResult) -> String {
    let churn = sweep.base.churn.is_some_and(|ch| ch.enabled());
    let mut out = String::from(
        "policy,load,x,seed,jobs,incomplete,mean_flowtime,p80_flowtime,p90_flowtime,\
         mean_resource,p80_resource,net_utility,utilization,backups",
    );
    if churn {
        out.push_str(",machines_failed,copies_lost,work_lost");
    }
    out.push('\n');
    for cell in &sweep.cells {
        let row = SummaryRow::from_result(&cell.result);
        let (policy, _) = &sweep.policies[cell.policy];
        let (load, x) = &sweep.loads[cell.load];
        let _ = write!(
            out,
            "{policy},{load},{x},{},{},{},{},{},{},{},{},{},{},{}",
            cell.seed,
            row.jobs,
            cell.result.incomplete,
            row.mean_flowtime,
            row.p80_flowtime,
            row.p90_flowtime,
            row.mean_resource,
            row.p80_resource,
            row.mean_net_utility,
            row.utilization,
            row.speculative_launches
        );
        if churn {
            let _ = write!(
                out,
                ",{},{},{}",
                cell.result.machines_failed, cell.result.copies_lost, cell.result.work_lost
            );
        }
        out.push('\n');
    }
    out
}

/// Simple labelled (x, y) series CSV: label,x,y.
pub fn xy_csv(series: &[(String, Vec<(f64, f64)>)]) -> String {
    let mut out = String::from("label,x,y\n");
    for (label, pts) in series {
        for (x, y) in pts {
            let _ = writeln!(out, "{label},{x},{y}");
        }
    }
    out
}

pub fn write_file(path: impl AsRef<Path>, content: &str) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        fs::create_dir_all(parent)?;
    }
    fs::write(path, content)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_all_rows() {
        let rows = vec![SummaryRow {
            scheduler: "sca".to_string(),
            jobs: 10,
            mean_flowtime: 1.5,
            p80_flowtime: 2.0,
            p90_flowtime: 3.0,
            mean_resource: 0.5,
            p80_resource: 0.7,
            mean_net_utility: -2.0,
            utilization: 0.4,
            speculative_launches: 12,
        }];
        let t = summary_table(&rows);
        assert!(t.contains("sca"));
        assert_eq!(t.lines().count(), 2);
    }

    #[test]
    fn xy_csv_format() {
        let s = xy_csv(&[("a".into(), vec![(1.0, 2.0), (3.0, 4.0)])]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "label,x,y");
        assert_eq!(lines[1], "a,1,2");
        assert_eq!(lines.len(), 3);
    }

    #[test]
    fn sweep_csv_one_row_per_cell() {
        use crate::experiment::CellResult;
        let result = SimResult {
            scheduler: "naive".to_string(),
            completed: Vec::new(),
            incomplete: 1,
            total_machine_time: 3.0,
            speculative_launches: 0,
            utilization: 0.5,
            horizon: 10.0,
            events_processed: 42,
            ticks_fired: 5,
            ticks_skipped: 5,
            peak_event_queue: 7,
            slot_hook_secs: 0.0,
            copies_lost: 3,
            work_lost: 1.5,
            machines_failed: 2,
            streamed: None,
        };
        let sweep = SweepResult {
            name: "t".into(),
            base: crate::config::SimConfig::default(),
            policies: vec![("naive".into(), f64::NAN)],
            loads: vec![("lambda2".into(), 2.0)],
            seeds: vec![1, 2],
            cells: vec![
                CellResult { policy: 0, load: 0, seed: 1, result: result.clone() },
                CellResult { policy: 0, load: 0, seed: 2, result },
            ],
        };
        let csv = sweep_csv(&sweep);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("policy,load,x,seed"));
        assert!(
            !lines[0].contains("copies_lost"),
            "zero-churn sweeps keep the pre-churn column set byte-identical"
        );
        assert!(lines[1].starts_with("naive,lambda2,2,1,"));
        assert!(lines[2].starts_with("naive,lambda2,2,2,"));

        // with churn enabled on the base config the loss columns appear
        let mut churned = sweep.clone();
        churned.base.churn = Some(crate::cluster::machine::ChurnConfig::new(100.0, 10.0));
        let csv = sweep_csv(&churned);
        let lines: Vec<&str> = csv.lines().collect();
        assert!(lines[0].ends_with("backups,machines_failed,copies_lost,work_lost"));
        assert!(lines[1].ends_with(",2,3,1.5"), "machines_failed,copies_lost,work_lost: {}", lines[1]);
    }

    #[test]
    fn cmf_csv_format() {
        let mut c = Cdf::new();
        c.extend([1.0, 2.0, 3.0]);
        let s = cmf_csv(&mut [("x", c)], 3);
        assert!(s.starts_with("label,x,cmf\n"));
        assert!(s.lines().count() > 3);
    }
}
