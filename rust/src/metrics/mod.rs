//! Per-job metrics and the report writers behind the figure harness.

pub mod report;

/// One completed job's accounting (the unit every paper CMF is built from).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobRecord {
    pub job: u32,
    pub arrival: f64,
    pub num_tasks: u32,
    pub mean_duration: f64,
    pub finish: f64,
    /// finish - arrival (Definition 1).
    pub flowtime: f64,
    /// gamma * total machine-time over all copies.
    pub resource: f64,
    /// Queueing delay: first task launch - arrival (w_i - a_i).
    pub wait: f64,
}

impl JobRecord {
    /// The paper's combined metric: utility (-flowtime) minus resource.
    pub fn net_utility(&self) -> f64 {
        -self.flowtime - self.resource
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_utility() {
        let r = JobRecord {
            job: 0,
            arrival: 1.0,
            num_tasks: 2,
            mean_duration: 1.0,
            finish: 4.0,
            flowtime: 3.0,
            resource: 0.5,
            wait: 1.0,
        };
        assert!((r.net_utility() + 3.5).abs() < 1e-12);
    }
}
