//! Per-job metrics and the report writers behind the figure harness.

pub mod report;

/// One completed job's accounting (the unit every paper CMF is built from).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct JobRecord {
    pub job: u32,
    pub arrival: f64,
    pub num_tasks: u32,
    pub mean_duration: f64,
    pub finish: f64,
    /// finish - arrival (Definition 1).
    pub flowtime: f64,
    /// gamma * total machine-time over all copies.
    pub resource: f64,
    /// Queueing delay: first task launch - arrival (w_i - a_i).
    pub wait: f64,
}

impl JobRecord {
    /// The paper's combined metric: utility (-flowtime) minus resource.
    pub fn net_utility(&self) -> f64 {
        -self.flowtime - self.resource
    }
}

/// Bounded-memory aggregation of completed-job records: Welford moments
/// plus P² quantile sketches for the percentiles the sweep CSV reports.
///
/// This is what a `--max-resident-jobs`-capped run keeps instead of the
/// full `Vec<JobRecord>`: each drained record is absorbed here and dropped,
/// so a million-job replay's metric state is O(1).
#[derive(Clone, Debug)]
pub struct StreamedJobStats {
    pub flowtime: crate::stats::Summary,
    pub resource: crate::stats::Summary,
    pub net_utility: crate::stats::Summary,
    pub flow_p80: crate::stats::P2Quantile,
    pub flow_p90: crate::stats::P2Quantile,
    pub res_p80: crate::stats::P2Quantile,
    /// Records absorbed (and recycled) so far.
    pub drained: u64,
}

impl StreamedJobStats {
    pub fn new() -> Self {
        StreamedJobStats {
            flowtime: crate::stats::Summary::new(),
            resource: crate::stats::Summary::new(),
            net_utility: crate::stats::Summary::new(),
            flow_p80: crate::stats::P2Quantile::new(0.8),
            flow_p90: crate::stats::P2Quantile::new(0.9),
            res_p80: crate::stats::P2Quantile::new(0.8),
            drained: 0,
        }
    }

    pub fn absorb(&mut self, r: &JobRecord) {
        self.flowtime.push(r.flowtime);
        self.resource.push(r.resource);
        self.net_utility.push(r.net_utility());
        self.flow_p80.push(r.flowtime);
        self.flow_p90.push(r.flowtime);
        self.res_p80.push(r.resource);
        self.drained += 1;
    }
}

impl Default for StreamedJobStats {
    fn default() -> Self {
        StreamedJobStats::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn net_utility() {
        let r = JobRecord {
            job: 0,
            arrival: 1.0,
            num_tasks: 2,
            mean_duration: 1.0,
            finish: 4.0,
            flowtime: 3.0,
            resource: 0.5,
            wait: 1.0,
        };
        assert!((r.net_utility() + 3.5).abs() < 1e-12);
    }
}
