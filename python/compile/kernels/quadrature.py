"""L1 Pallas kernels: batched survival-power quadrature.

Every expectation the paper's optimizers need reduces to quadrature of
survival-power integrands on a shared normalized grid (see grids.py).  The
kernels below implement those reductions as Pallas kernels:

  elementwise stage (pow/exp/log1p in VMEM)  ->  weighted reduction (matvec)

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and this whole package only runs at build time.  The
BlockSpec structure is nevertheless written the way a real TPU lowering
wants it — tile over the batch axis, keep the quadrature grid resident in
VMEM, reduce against a broadcast weight vector (DESIGN.md §2).

Correctness oracle: ``ref.py``; pytest asserts allclose on every kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import grids

# batch-axis block sizes (VMEM budget math in DESIGN.md §2)
B_BLK = 8  # flowtime kernel: [B_BLK, G, T] f32 tile ~= 4 MiB
S_BLK = 8  # sigma kernels:   [S_BLK, TE, V] f32 tile ~= 2 MiB

_INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls


# ---------------------------------------------------------------------------
# flowtime table kernel
# ---------------------------------------------------------------------------


def _flowtime_kernel(m_ref, beta_ref, logu_ref, w_ref, out_ref):
    """out[b, g] = 1 + sum_t w_t * (1 - (1 - u_t^-beta_g)^m_b)."""
    m = m_ref[...]  # [B_BLK]
    beta = beta_ref[...]  # [G]
    logu = logu_ref[...]  # [T]
    w = w_ref[...]  # [T]
    # survival of the per-task min at t = mu * u: p[g, t] = u^-beta
    logp = -beta[:, None] * logu[None, :]  # [G, T] (<= 0)
    p = jnp.exp(logp)
    # stable 1 - (1-p)^m: -expm1(m * log1p(-p)); log1p(-1) = -inf is exact.
    base = jnp.log1p(-jnp.minimum(p, 1.0))  # [G, T]
    integ = -jnp.expm1(m[:, None, None] * base[None, :, :])  # [B_BLK, G, T]
    out_ref[...] = 1.0 + jax.lax.dot_general(
        integ.reshape(-1, integ.shape[-1]),
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(m.shape[0], beta.shape[0])


@functools.partial(jax.jit, static_argnames=())
def flowtime_table(m, beta):
    """Pallas version of ref.flowtime_table: [B],[G] -> [B,G]."""
    u, w = grids.flow_grid()
    logu = jnp.log(jnp.asarray(u))
    w = jnp.asarray(w)
    b, g, t = m.shape[0], beta.shape[0], logu.shape[0]
    assert b % B_BLK == 0, f"batch {b} must be a multiple of {B_BLK}"
    return pl.pallas_call(
        _flowtime_kernel,
        grid=(b // B_BLK,),
        in_specs=[
            pl.BlockSpec((B_BLK,), lambda i: (i,)),
            pl.BlockSpec((g,), lambda i: (0,)),
            pl.BlockSpec((t,), lambda i: (0,)),
            pl.BlockSpec((t,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((B_BLK, g), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, g), jnp.float32),
        interpret=_INTERPRET,
    )(m, beta, logu, w)


# ---------------------------------------------------------------------------
# SDA tau kernel:  tau[s, c] = c * int_0^inf S(t)^(c-1) S(max(t/(1-s), L))/S(L) dt
# ---------------------------------------------------------------------------


def _sda_tau_kernel(sigma_ref, c_ref, scal_ref, t_ref, w_ref, out_ref):
    sigma = sigma_ref[...]  # [S_BLK]
    c = c_ref[...]  # [C]
    alpha = scal_ref[0]
    s = scal_ref[1]
    t = t_ref[...]  # [T]
    w = w_ref[...]  # [T]
    mu = (alpha - 1.0) / alpha
    logmu = jnp.log(mu)
    L = jnp.maximum(mu, sigma / (1.0 - s))  # [S_BLK]
    log_sl = alpha * (logmu - jnp.log(L))  # log S(L) (L >= mu)
    # log survival of a fresh copy at t:  min(0, alpha*(log mu - log t))
    log_sf = jnp.minimum(0.0, alpha * (logmu - jnp.log(t)))  # [T]
    pow_fresh = jnp.exp((c[:, None] - 1.0) * log_sf[None, :])  # [C, T]
    targ = jnp.maximum(t[None, :] / (1.0 - s), L[:, None])  # [S_BLK, T]
    sf_orig = jnp.exp(
        jnp.minimum(0.0, alpha * (logmu - jnp.log(targ))) - log_sl[:, None]
    )  # [S_BLK, T]
    prod = sf_orig[:, None, :] * pow_fresh[None, :, :]  # [S_BLK, C, T]
    tail = jax.lax.dot_general(
        prod.reshape(-1, prod.shape[-1]),
        w,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(sigma.shape[0], c.shape[0])
    out_ref[...] = c[None, :] * tail


def sda_tau(alpha, s, sigma, c):
    """Pallas version of ref.sda_tau: scalars + [S],[C] -> [S,C]."""
    t, w = grids.tau_grid()
    t, w = jnp.asarray(t), jnp.asarray(w)
    ns, nc, nt = sigma.shape[0], c.shape[0], t.shape[0]
    assert ns % S_BLK == 0, f"sigma grid {ns} must be a multiple of {S_BLK}"
    scal = jnp.stack([jnp.asarray(alpha, jnp.float32), jnp.asarray(s, jnp.float32)])
    return pl.pallas_call(
        _sda_tau_kernel,
        grid=(ns // S_BLK,),
        in_specs=[
            pl.BlockSpec((S_BLK,), lambda i: (i,)),
            pl.BlockSpec((nc,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((nt,), lambda i: (0,)),
            pl.BlockSpec((nt,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((S_BLK, nc), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((ns, nc), jnp.float32),
        interpret=_INTERPRET,
    )(sigma, c, scal, t, w)


# ---------------------------------------------------------------------------
# ESE resource kernel: double quadrature over (t, asktime) per sigma
# ---------------------------------------------------------------------------


def _ese_kernel(sigma_ref, scal_ref, t_ref, wt_ref, v_ref, wv_ref, out_ref):
    sigma = sigma_ref[...]  # [S_BLK]
    alpha = scal_ref[0]
    t = t_ref[...]  # [TE]
    wt = wt_ref[...]  # [TE]
    v = v_ref[...]  # [V]
    wv = wv_ref[...]  # [V]
    mu = (alpha - 1.0) / alpha
    logmu = jnp.log(mu)

    # term1: E[x; x <= max(sigma, mu)] closed form
    L1 = jnp.maximum(sigma, mu)
    sl1 = jnp.exp(alpha * (logmu - jnp.log(L1)))
    term1 = jnp.where(sigma >= mu, 1.0 - L1 * sl1 * alpha / (alpha - 1.0), 0.0)

    # term2 inner: for x = t > L1, asktime A = (t - sigma) * v
    span = jnp.maximum(t[None, :] - sigma[:, None], 0.0)  # [S_BLK, TE]
    x_ask = span[:, :, None] * v[None, None, :]  # [S_BLK, TE, V]
    rem = jnp.maximum(t[None, :, None] - x_ask, 0.0)
    # E[min(rem, t_new)] closed form (integral of survival):
    head = jnp.minimum(rem, mu)
    tail = (mu / (alpha - 1.0)) * -jnp.expm1(
        (alpha - 1.0) * (logmu - jnp.log(jnp.maximum(rem, mu)))
    )
    inner = x_ask + 2.0 * (head + tail)  # [S_BLK, TE, V]
    inner_int = jax.lax.dot_general(
        inner.reshape(-1, inner.shape[-1]),
        wv,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    ).reshape(span.shape)  # [S_BLK, TE]
    cond = sigma[:, None] + (span / t[None, :]) * inner_int
    logf = jnp.log(alpha) + alpha * logmu - (alpha + 1.0) * jnp.log(t)  # [TE]
    f = jnp.exp(logf)[None, :] * (t[None, :] > L1[:, None])
    term2 = jax.lax.dot_general(
        cond * f,
        wt,
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    out_ref[...] = term1 + term2


def ese_resource(alpha, sigma):
    """Pallas version of ref.ese_resource: scalar alpha + [S] -> [S]."""
    t, wt = grids.ese_t_grid()
    v, wv = grids.unit_trap(grids.V)
    t, wt, v, wv = map(jnp.asarray, (t, wt, v, wv))
    ns = sigma.shape[0]
    assert ns % S_BLK == 0
    scal = jnp.stack([jnp.asarray(alpha, jnp.float32)])
    return pl.pallas_call(
        _ese_kernel,
        grid=(ns // S_BLK,),
        in_specs=[
            pl.BlockSpec((S_BLK,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((t.shape[0],), lambda i: (0,)),
            pl.BlockSpec((t.shape[0],), lambda i: (0,)),
            pl.BlockSpec((v.shape[0],), lambda i: (0,)),
            pl.BlockSpec((v.shape[0],), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((S_BLK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((ns,), jnp.float32),
        interpret=_INTERPRET,
    )(sigma, scal, t, wt, v, wv)
