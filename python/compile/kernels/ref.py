"""Pure-jnp oracle for every L1 kernel — the CORE correctness signal.

Each function here computes exactly what the corresponding Pallas kernel in
``quadrature.py`` computes, with the same grids, but written as plain
vectorized jnp.  pytest asserts kernel-vs-ref allclose; the model layer
(``model.py``) can also be built against the oracle (``use_pallas=False``)
to isolate kernel bugs from model bugs.

Paper mapping (Xu & Lau 2014):
  * ``flowtime_table``  — E[max_j min_k t_jk] under Pareto, Eq.(11)-(12)
  * ``emin_coeff``      — E[min of c Pareto copies] / mu, Sec. III-B
  * ``sda_tau``         — E[c * d | straggler detected], Eq.(26)
  * ``sda_resource``    — per-task resource objective of P3, Eq.(21)-(28)
  * ``ese_resource``    — E[R_j^i] of the ESE analysis, Eq.(30)-(33)
  * ``p2_score_table`` / ``p2_dual_step`` — gradient projection, Sec. IV-A
"""

from __future__ import annotations

import jax.numpy as jnp

from . import grids

_NEG = -1.0e30  # score mask value


# ---------------------------------------------------------------------------
# survival helpers (Pareto(mu, alpha): S(t) = min(1, (mu/t)^alpha))
# ---------------------------------------------------------------------------


def pareto_sf(t, mu, alpha):
    """Pareto survival function, elementwise, safe at t <= mu and t = 0."""
    t = jnp.maximum(t, 1e-30)
    return jnp.minimum(1.0, jnp.exp(alpha * (jnp.log(mu) - jnp.log(t))))


def survival_power(p, k):
    """(1 - (1 - p)^k) computed stably for p in [0, 1], k >= 0."""
    # log1p(-p) -> -inf at p=1; k * -inf -> -inf; -expm1(-inf) -> 1.  exact.
    return -jnp.expm1(k * jnp.log1p(-jnp.minimum(p, 1.0)))


# ---------------------------------------------------------------------------
# flowtime table: I(beta, m) = E[max of m mins] / mu  (normalized)
# ---------------------------------------------------------------------------


def flowtime_table(m, beta):
    """Normalized expected job span E[d]/mu (Eq.11-12).

    Args:
      m:    [B]  number of tasks per job (float, >= 1)
      beta: [G]  alpha * c for each candidate clone count c (> 1)

    Returns:
      I: [B, G] with E[max_{j<=m} min_{k<=c} t_jk] = mu * I(alpha*c, m).
    """
    u, w = grids.flow_grid()  # [T], [T]
    u, w = jnp.asarray(u), jnp.asarray(w)
    # p[g, t] = u_t^(-beta_g): survival of the per-task min at t = mu*u.
    p = jnp.exp(-beta[:, None] * jnp.log(u)[None, :])  # [G, T]
    integ = survival_power(p[None, :, :], m[:, None, None])  # [B, G, T]
    return 1.0 + jnp.einsum("bgt,t->bg", integ, w)


def emin_coeff(beta):
    """E[min of c Pareto(mu, alpha) copies]/mu = beta/(beta-1), beta = alpha*c."""
    return beta / (beta - 1.0)


# ---------------------------------------------------------------------------
# SDA (P3): tau and the per-task resource objective
# ---------------------------------------------------------------------------


def sda_tau(alpha, s, sigma, c):
    """E[c * d | straggler detected] for unit-mean Pareto tasks (Eq.26).

    d = min((1-s) * t1, min of c-1 fresh copies), conditioned on the
    detection event (1-s) * t1 > sigma * E[x] (E[x] = 1).

    Args:
      alpha: scalar heavy-tail order (> 1)
      s:     scalar detection fraction in (0, 1)
      sigma: [S] threshold multipliers
      c:     [C] total copy counts (>= 1; c = 1 means no duplicate)

    Returns: tau [S, C]
    """
    mu = (alpha - 1.0) / alpha  # unit mean
    t, w = grids.tau_grid()
    t, w = jnp.asarray(t), jnp.asarray(w)
    L = jnp.maximum(mu, sigma / (1.0 - s))  # [S]
    s_l = pareto_sf(L, mu, alpha)  # [S]
    # P(d > t) = S(t)^(c-1) * S(max(t/(1-s), L)) / S(L)
    sf_fresh = pareto_sf(t, mu, alpha)  # [T]
    pow_fresh = jnp.exp(
        (c[:, None] - 1.0) * jnp.log(jnp.maximum(sf_fresh, 1e-38))[None, :]
    )  # [C, T]
    sf_orig = (
        pareto_sf(jnp.maximum(t[None, :] / (1.0 - s), L[:, None]), mu, alpha)
        / s_l[:, None]
    )  # [S, T]
    tail = jnp.einsum("st,ct,t->sc", sf_orig, pow_fresh, w)  # [S, C]
    return c[None, :] * tail


def sda_resource(alpha, s, sigma, c):
    """Unconditional per-task resource E[R] (unit-mean Pareto), Eq.(21).

    R = t1 when no straggler is detected; R = s*t1 + c*d when one is:
      E[R] = s*E[t1] + (1-s)*E[t1; t1 <= L] + P(t1 > L) * tau(c, sigma).

    Returns [S, C].
    """
    mu = (alpha - 1.0) / alpha
    L = jnp.maximum(mu, sigma / (1.0 - s))
    s_l = pareto_sf(L, mu, alpha)
    # E[t1; t1 > L] = L * S(L) * alpha/(alpha-1) for L >= mu
    e_tail = L * s_l * alpha / (alpha - 1.0)
    e_head = 1.0 - e_tail
    tau = sda_tau(alpha, s, sigma, c)
    return s + (1.0 - s) * e_head[:, None] + s_l[:, None] * tau


# ---------------------------------------------------------------------------
# ESE heavy-load analysis: E[R](sigma) per Eq.(30)-(33)
# ---------------------------------------------------------------------------


def emin_fresh(tau, mu, alpha):
    """E[min(tau, t_new)] = integral_0^tau S(w) dw for Pareto(mu, alpha)."""
    tau = jnp.maximum(tau, 0.0)
    head = jnp.minimum(tau, mu)
    tail = (mu / (alpha - 1.0)) * -jnp.expm1(
        (alpha - 1.0) * (jnp.log(mu) - jnp.log(jnp.maximum(tau, mu)))
    )
    return head + tail


def ese_resource(alpha, sigma):
    """E[R]/E[x] of a single task under the ESE asktime model (Fig. 4).

    Unit-mean Pareto; a running task of (hidden) duration t is checked at an
    asktime uniform on [0, t]; a duplicate is launched if the remaining time
    t - A exceeds sigma * E[x] (Eq.30-33).

    Returns: [S]
    """
    mu = (alpha - 1.0) / alpha
    t, wt = grids.ese_t_grid()
    v, wv = grids.unit_trap(grids.V)
    t, wt, v, wv = map(jnp.asarray, (t, wt, v, wv))
    sig = jnp.asarray(sigma)

    # term1: tasks with x <= sigma never duplicate: E[x; x <= sigma]
    # (E[x; x <= L] = 1 - L*S(L)*alpha/(alpha-1) for L >= mu, 0 for L < mu)
    L1 = jnp.maximum(sig, mu)
    term1 = jnp.where(
        sig >= mu,
        1.0 - L1 * pareto_sf(L1, mu, alpha) * alpha / (alpha - 1.0),
        0.0,
    )

    # term2: tasks with x = t > max(sigma, mu):
    #   E[R | x=t] = sigma + ((t-sigma)/t) * int_0^1 [(t-sigma)v
    #                + 2*emin_fresh(t - (t-sigma)v)] dv       (Eq.32-33)
    span = jnp.maximum(t[None, :] - sig[:, None], 0.0)  # [S, T]
    x_ask = span[:, :, None] * v[None, None, :]  # [S, T, V]
    rem = t[None, :, None] - x_ask  # duration left when duplicated
    inner = x_ask + 2.0 * emin_fresh(rem, mu, alpha)  # [S, T, V]
    inner_int = jnp.einsum("stv,v->st", inner, wv)  # [S, T]
    cond = sig[:, None] + (span / t[None, :]) * inner_int  # [S, T]
    # density f(t) = alpha * mu^alpha * t^-(alpha+1), support t >= mu
    logf = jnp.log(alpha) + alpha * jnp.log(mu) - (alpha + 1.0) * jnp.log(t)
    f = jnp.exp(logf)[None, :] * (t[None, :] > L1[:, None])  # [S, T]
    term2 = jnp.einsum("st,st,t->s", cond, f, wt)
    return term1 + term2


# ---------------------------------------------------------------------------
# P2 dual machinery (gradient projection, Sec. IV-A)
# ---------------------------------------------------------------------------


def p2_score_table(mu, m, age, gamma, alpha, cg):
    """Static part A[b, g] of the dual objective.

    With U = -E[t] (the paper's worked special case):
      A[b,g] = -(mu_b * I(alpha*c_g, m_b) + age_b)
               - gamma * m_b * c_g * mu_b * beta_g/(beta_g - 1).
    """
    beta = alpha * cg  # [G]
    flow = flowtime_table(m, beta)  # [B, G]
    e_min = emin_coeff(beta)[None, :]  # [1, G]
    return -(mu[:, None] * flow + age[:, None]) - gamma * (
        m[:, None] * cg[None, :] * mu[:, None] * e_min
    )


def p2_dual_step(state, table, m, mask, n_avail, r, cg, etas):
    """One gradient-projection iteration (the paper's update equations).

    state = (nu, xi[B], h[B]);  returns (new_state, c[B]).
    """
    nu, xi, h = state
    eta1, eta2, eta3 = etas
    price = (nu * m + xi - h)[:, None] * cg[None, :]  # [B, G]
    score = table - price
    score = jnp.where(cg[None, :] <= r, score, _NEG)
    idx = jnp.argmax(score, axis=1)
    c = cg[idx] * mask  # inactive rows contribute 0
    nu = jnp.maximum(0.0, nu + eta1 * (jnp.sum(m * c) - n_avail))
    xi = jnp.maximum(0.0, xi + eta2 * (c - r) * mask)
    h = jnp.maximum(0.0, h + eta3 * (1.0 - c) * mask)
    return (nu, xi, h), c
