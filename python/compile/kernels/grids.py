"""Shared quadrature grids for the Pallas kernels and the jnp oracle.

All expectation integrals in the paper reduce, after normalizing task
durations by the Pareto scale (``t = mu * u``) or by the mean (``E[x] = 1``),
to integrals of smooth survival-power integrands over ``[0, inf)`` with
polynomial tails.  We evaluate them with trapezoid quadrature on log-spaced
grids; the change of variables ``u = exp(x)`` folds the Jacobian into the
weights so kernels only ever do an elementwise stage followed by a weighted
reduction.

The grid shapes here are the *static* shapes baked into the AOT artifacts —
rust never re-derives them; it reads artifacts/manifest.json.
"""

from __future__ import annotations

import numpy as np

# --- static shapes baked into the AOT artifacts -------------------------
B = 64  # P2 batch: max pending jobs solved per scheduling slot
G = 64  # candidate clone-count grid size (c in [1, C_MAX])
T = 1024  # outer quadrature grid (flowtime / tau integrals)
TE = 512  # outer t-grid for the ESE resource integral
V = 128  # inner asktime grid for the ESE resource integral
S = 128  # sigma grid size
C_MAX = 16.0  # upper end of the clone-count grid
SIGMA_LO, SIGMA_HI = 0.05, 6.0
P2_ITERS = 250  # dual gradient-projection iterations (fixed, unrolled by scan)


def c_grid() -> np.ndarray:
    """Candidate clone counts: [1, C_MAX], G points (first point exactly 1)."""
    return np.linspace(1.0, C_MAX, G, dtype=np.float32)


def sigma_grid() -> np.ndarray:
    """Straggler-threshold multipliers sigma, (0, 6]."""
    return np.linspace(SIGMA_LO, SIGMA_HI, S, dtype=np.float32)


def log_trap(lo: float, hi: float, n: int) -> tuple[np.ndarray, np.ndarray]:
    """Log-spaced nodes ``u`` on [lo, hi] and trapezoid weights for
    ``integral g(u) du`` (Jacobian folded in): returns (u, w) with
    ``sum(w * g(u)) ~ integral_lo^hi g``."""
    x = np.linspace(np.log(lo), np.log(hi), n)
    u = np.exp(x)
    dx = x[1] - x[0]
    wx = np.full(n, dx)
    wx[0] *= 0.5
    wx[-1] *= 0.5
    return u.astype(np.float32), (wx * u).astype(np.float32)


def flow_grid() -> tuple[np.ndarray, np.ndarray]:
    """Grid for the normalized flowtime integral
    ``I(beta, m) = 1 + integral_1^inf (1 - (1 - u^-beta)^m) du``.

    Tail beyond U contributes ~ m * U^(1-beta) / (beta-1); with beta >= 2
    and m <= 1e4, U = 1e7 keeps it < 1e-3 absolute.
    """
    return log_trap(1.0, 1.0e7, T)


def tau_grid() -> tuple[np.ndarray, np.ndarray]:
    """Grid for the SDA tau integral over t in (0, inf) (unit-mean Pareto).

    The integrand is bounded by 1 and supported essentially on
    [mu*(1-s), ~1e5]; mu >= 1/2 for alpha >= 2 wait-free lower bound 1e-3."""
    return log_trap(1.0e-3, 1.0e5, T)


def ese_t_grid() -> tuple[np.ndarray, np.ndarray]:
    """Outer grid over task durations t for the ESE resource integral
    (unit-mean Pareto; mu = (alpha-1)/alpha >= 1/4 for alpha in [4/3, inf))."""
    return log_trap(1.0e-2, 1.0e5, TE)


def unit_trap(n: int) -> tuple[np.ndarray, np.ndarray]:
    """Linear nodes/weights on [0, 1] for the inner asktime integral."""
    v = np.linspace(0.0, 1.0, n)
    dv = v[1] - v[0]
    w = np.full(n, dv)
    w[0] *= 0.5
    w[-1] *= 0.5
    return v.astype(np.float32), w.astype(np.float32)
