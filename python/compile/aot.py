# Emit HLO text (NOT .serialize()) — jax >= 0.5 writes HloModuleProto with
# 64-bit instruction ids which the runtime's xla_extension 0.5.1 rejects
# (`proto.id() <= INT_MAX`); the HLO *text* parser reassigns ids and
# round-trips cleanly.  See /opt/xla-example/gen_hlo.py.
"""AOT compile path: lower every L2 entry point to artifacts/*.hlo.txt.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Writes one HLO text file per entry point plus manifest.json describing the
exact input/output shapes and the static grids the rust runtime needs to
interpret the outputs.  `make artifacts` invokes this once; nothing in this
package is imported at run time.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import grids


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the interchange format).

    CRITICAL: print with ``print_large_constants`` — the default printer
    elides arrays beyond a handful of elements as ``constant({...})``, which
    the 0.5.1 text parser silently reads as zeros (the quadrature weight
    vectors and clone-count grids are baked-in constants).
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # jax 0.8's metadata carries source_end_line/column attributes the 0.5.1
    # text parser rejects; drop metadata entirely.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "elided constants survived the printer"
    return text


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_points():
    """name -> (fn, example_args, manifest entry)."""
    B, S, C, K = grids.B, grids.S, model.SDA_C, grids.P2_ITERS
    batch = [_f32(B), _f32(B), _f32(B), _f32(B)]

    def p2_solver(mu, m, age, mask, params):
        return model.p2_solve(mu, m, age, mask, params)

    def p2_trace(mu, m, age, mask, params):
        return model.p2_solve_traced(mu, m, age, mask, params)

    def sigma_curve(params):
        return model.sigma_curve(params)

    def sda_opt(params):
        return model.sda_opt(params)

    return {
        "p2_solver": (
            p2_solver,
            batch + [_f32(4)],
            {
                "inputs": [
                    {"name": "mu", "shape": [B]},
                    {"name": "m", "shape": [B]},
                    {"name": "age", "shape": [B]},
                    {"name": "mask", "shape": [B]},
                    {"name": "params(n_avail,gamma,r,alpha)", "shape": [4]},
                ],
                "outputs": [
                    {"name": "c_star", "shape": [B]},
                    {"name": "nu", "shape": []},
                    {"name": "obj", "shape": []},
                ],
            },
        ),
        "p2_trace": (
            p2_trace,
            batch + [_f32(4)],
            {
                "inputs": [
                    {"name": "mu", "shape": [B]},
                    {"name": "m", "shape": [B]},
                    {"name": "age", "shape": [B]},
                    {"name": "mask", "shape": [B]},
                    {"name": "params(n_avail,gamma,r,alpha)", "shape": [4]},
                ],
                "outputs": [
                    {"name": "c_trace", "shape": [K, B]},
                    {"name": "nu_trace", "shape": [K]},
                ],
            },
        ),
        "sigma_curve": (
            sigma_curve,
            [_f32(1)],
            {
                "inputs": [{"name": "params(alpha)", "shape": [1]}],
                "outputs": [
                    {"name": "sigma_grid", "shape": [S]},
                    {"name": "e_resource", "shape": [S]},
                ],
            },
        ),
        "sda_opt": (
            sda_opt,
            [_f32(2)],
            {
                "inputs": [{"name": "params(alpha,s)", "shape": [2]}],
                "outputs": [
                    {"name": "tau", "shape": [S, C]},
                    {"name": "resource", "shape": [S, C]},
                ],
            },
        ),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single entry point")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "statics": {
            "batch": grids.B,
            "c_grid": {"lo": 1.0, "hi": grids.C_MAX, "n": grids.G},
            "sigma_grid": {"lo": grids.SIGMA_LO, "hi": grids.SIGMA_HI, "n": grids.S},
            "sda_c_max": model.SDA_C,
            "p2_iters": grids.P2_ITERS,
            "etas": list(model.ETAS),
        },
        "artifacts": {},
    }
    for name, (fn, example, entry) in entry_points().items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        entry["file"] = f"{name}.hlo.txt"
        manifest["artifacts"][name] = entry
        print(f"wrote {path} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
