# L2: the paper's optimization computations as jax functions, calling the
# L1 Pallas kernels.  These are the compute graphs that aot.py lowers to
# HLO text; the rust coordinator executes them via PJRT at run time.
#
# Entry points (shapes are the static ones from kernels/grids.py):
#   p2_solve        — Sec. IV-A gradient projection for P2 (SCA's hot path)
#   p2_solve_traced — same, emitting the full dual iterate trace (Fig. 1)
#   sigma_curve     — Eq.(30)-(33) E[R](sigma) curve (Fig. 4, ESE's sigma*)
#   sda_opt         — Eq.(26)-(28) tau and E[R] tables (SDA's c*, sigma*)
"""JAX model layer (build-time only; never imported at run time)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import grids, quadrature, ref

# Oracle/kernel switch: the lowered artifact uses the Pallas kernels; the
# pytest suite also evaluates against the oracle to isolate kernel bugs.
_KERNELS = {
    True: quadrature,
    False: ref,
}

ETAS = (0.2, 0.3, 0.4)  # paper's Fig.1 step sizes eta_1..3
SDA_C = 8  # SDA candidate copy counts 1..8


def _p2_table(mu, m, age, gamma, alpha, use_pallas=True):
    """Static dual-objective table A[b, g] (ref.p2_score_table, kernel-backed)."""
    k = _KERNELS[use_pallas]
    cg = jnp.asarray(grids.c_grid())
    beta = alpha * cg
    m = jnp.maximum(m, 1.0)  # padded rows carry m = 0; keep the table finite
    flow = k.flowtime_table(m, beta)  # [B, G]
    e_min = ref.emin_coeff(beta)[None, :]
    table = -(mu[:, None] * flow + age[:, None]) - gamma * (
        m[:, None] * cg[None, :] * mu[:, None] * e_min
    )
    return table, cg


def _p2_scan(table, cg, m, mask, n_avail, r, iters):
    """Run the gradient projection for a fixed number of iterations.

    The capacity subgradient sum(m*c) - N is O(N), so eta_1 is scaled by
    1/N to keep the price increment per iteration O(eta_1); the paper's
    Matlab experiment uses raw steps on a 100-machine slot, which is the
    same magnitude.  Primal recovery uses the tail-averaged multipliers
    (ergodic iterate of the subgradient method).
    """
    eta1, eta2, eta3 = ETAS
    etas = (eta1 / jnp.maximum(n_avail, 1.0), eta2, eta3)

    def step(state, _):
        state, c = ref.p2_dual_step(state, table, m, mask, n_avail, r, cg, etas)
        return state, (c, state[0], state[1], state[2])

    b = m.shape[0]
    init = (jnp.float32(0.1), jnp.full((b,), 0.1), jnp.full((b,), 0.1))
    state, (c_tr, nu_tr, xi_tr, h_tr) = jax.lax.scan(step, init, None, length=iters)
    # tail-average the duals over the last half of the run
    half = iters // 2
    nu_bar = jnp.mean(nu_tr[half:], axis=0)
    xi_bar = jnp.mean(xi_tr[half:], axis=0)
    h_bar = jnp.mean(h_tr[half:], axis=0)
    return (nu_bar, xi_bar, h_bar), c_tr, nu_tr


def p2_solve(mu, m, age, mask, params, use_pallas=True):
    """Solve P2 for one scheduling slot.

    Args:
      mu, m, age, mask: [B] job batch (Pareto scale, task count, current age
        l - a_i, active mask in {0,1}); padded rows have mask 0.
      params: [4] = (n_avail, gamma, r, alpha).

    Returns (c_star [B], nu [], obj []): continuous per-task clone counts
    (rust rounds + repairs), final capacity price, primal objective value.
    """
    n_avail, gamma, r, alpha = params[0], params[1], params[2], params[3]
    table, cg = _p2_table(mu, m, age, gamma, alpha, use_pallas)
    state, _, _ = _p2_scan(table, cg, m, mask, n_avail, r, grids.P2_ITERS)
    # primal point from the final multipliers
    nu, xi, h = state
    score = table - (nu * m + xi - h)[:, None] * cg[None, :]
    score = jnp.where(cg[None, :] <= r, score, -1.0e30)
    idx = jnp.argmax(score, axis=1)
    c = cg[idx] * mask
    obj = jnp.sum(jnp.take_along_axis(table, idx[:, None], axis=1)[:, 0] * mask)
    return c, nu, obj


def p2_solve_traced(mu, m, age, mask, params, use_pallas=True):
    """p2_solve variant emitting the full iterate trace (Fig. 1)."""
    n_avail, gamma, r, alpha = params[0], params[1], params[2], params[3]
    table, cg = _p2_table(mu, m, age, gamma, alpha, use_pallas)
    _, c_trace, nu_trace = _p2_scan(table, cg, m, mask, n_avail, r, grids.P2_ITERS)
    # Cesaro-averaged primal iterates: the convergent sequence Fig.1 plots
    k = jnp.arange(1, c_trace.shape[0] + 1, dtype=jnp.float32)[:, None]
    c_bar = jnp.cumsum(c_trace, axis=0) / k
    return c_bar, nu_trace


def sigma_curve(params, use_pallas=True):
    """E[R](sigma)/E[x] over the static sigma grid; params = [1] = (alpha,)."""
    k = _KERNELS[use_pallas]
    sg = jnp.asarray(grids.sigma_grid())
    return (sg, k.ese_resource(params[0], sg))


def sda_opt(params, use_pallas=True):
    """SDA tables: params = [2] = (alpha, s).

    Returns (tau [S, C], resource [S, C]) over the static sigma grid and
    c in {1..SDA_C}; rust extracts c*(sigma) = argmin_c tau and
    sigma* = argmin_sigma resource[., c*(sigma)] (Theorem 3 verification).
    """
    alpha, s = params[0], params[1]
    sg = jnp.asarray(grids.sigma_grid())
    cc = jnp.arange(1, SDA_C + 1, dtype=jnp.float32)
    k = _KERNELS[use_pallas]
    tau = k.sda_tau(alpha, s, sg, cc)
    mu = (alpha - 1.0) / alpha
    L = jnp.maximum(mu, sg / (1.0 - s))
    s_l = ref.pareto_sf(L, mu, alpha)
    e_tail = L * s_l * alpha / (alpha - 1.0)
    e_head = 1.0 - e_tail
    resource = s + (1.0 - s) * e_head[:, None] + s_l[:, None] * tau
    return tau, resource
