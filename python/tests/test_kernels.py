# pytest: Pallas kernel vs pure-jnp oracle — the CORE correctness signal.
"""Kernel-level tests: exact closed forms, Monte Carlo ground truth, and
kernel-vs-oracle agreement (including hypothesis sweeps over shapes/params).
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import grids, quadrature, ref

RNG = np.random.default_rng(20140213)


def f32(x):
    return jnp.asarray(np.asarray(x, dtype=np.float32))


# ---------------------------------------------------------------------------
# flowtime table
# ---------------------------------------------------------------------------


class TestFlowtime:
    def test_m1_is_emin(self):
        """m=1: E[max of 1 min] = E[min of c] = beta/(beta-1) exactly."""
        beta = f32([1.5, 2.0, 4.0, 8.0, 16.0])
        got = ref.flowtime_table(f32([1.0]), beta)[0]
        np.testing.assert_allclose(got, beta / (beta - 1.0), rtol=1e-3)

    def test_m2_beta2_exact(self):
        """E[max of 2 Pareto(1,2)] = 8/3 by direct integration."""
        got = float(ref.flowtime_table(f32([2.0]), f32([2.0]))[0, 0])
        assert abs(got - 8.0 / 3.0) < 2e-4

    def test_monte_carlo(self):
        """Quadrature matches simulation for a mid-sized job."""
        m, beta = 20, 4.0
        samp = (RNG.pareto(beta, size=(200_000, m)) + 1.0).max(axis=1)
        got = float(ref.flowtime_table(f32([m]), f32([beta]))[0, 0])
        assert abs(got - samp.mean()) < 3.0 * samp.std() / np.sqrt(len(samp)) + 5e-3

    def test_monotone_decreasing_in_c(self):
        """More clones -> shorter expected span (cloning helps)."""
        beta = 2.0 * f32(grids.c_grid())
        row = np.asarray(ref.flowtime_table(f32([50.0]), beta))[0]
        assert np.all(np.diff(row) < 0)

    def test_monotone_increasing_in_m(self):
        """More tasks -> longer expected span (max order statistic)."""
        col = np.asarray(ref.flowtime_table(f32([1, 10, 100, 1000]), f32([4.0])))[:, 0]
        assert np.all(np.diff(col) > 0)

    def test_kernel_matches_ref(self):
        m = f32(RNG.integers(1, 101, grids.B))
        beta = 2.0 * f32(grids.c_grid())
        a = np.asarray(ref.flowtime_table(m, beta))
        b = np.asarray(quadrature.flowtime_table(m, beta))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)

    @settings(max_examples=20, deadline=None)
    @given(
        alpha=st.floats(1.3, 6.0),
        m_lo=st.integers(1, 5000),
        seed=st.integers(0, 2**31),
    )
    def test_kernel_matches_ref_hypothesis(self, alpha, m_lo, seed):
        rng = np.random.default_rng(seed)
        m = f32(rng.integers(m_lo, m_lo + 100, grids.B))
        beta = alpha * f32(grids.c_grid())
        a = np.asarray(ref.flowtime_table(m, beta))
        b = np.asarray(quadrature.flowtime_table(m, beta))
        assert np.isfinite(a).all()
        assert (a >= 1.0 - 1e-5).all()
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


class TestEminCoeff:
    def test_closed_form(self):
        np.testing.assert_allclose(
            ref.emin_coeff(f32([2.0, 4.0])), [2.0, 4.0 / 3.0], rtol=1e-6
        )

    def test_decreasing(self):
        beta = 2.0 * f32(grids.c_grid())
        coeff = np.asarray(ref.emin_coeff(beta))
        assert np.all(np.diff(coeff) < 0)


# ---------------------------------------------------------------------------
# SDA tau / resource (P3, Theorem 3)
# ---------------------------------------------------------------------------


class TestSdaTau:
    def test_c1_closed_form(self):
        """tau(1, sigma) = (1-s) * L * alpha/(alpha-1): no duplicate launched."""
        alpha, s = 2.0, 0.2
        sg = f32([0.5, 1.0, 2.0, 4.0])
        tau = np.asarray(ref.sda_tau(alpha, s, sg, f32([1.0])))[:, 0]
        mu = (alpha - 1.0) / alpha
        L = np.maximum(mu, np.asarray(sg) / (1.0 - s))
        np.testing.assert_allclose(tau, (1 - s) * L * alpha / (alpha - 1), rtol=2e-3)

    def test_monte_carlo_c2(self):
        alpha, s, sigma = 2.0, 0.2, 1.0
        mu = 0.5
        L = max(mu, sigma / (1 - s))
        t1 = (RNG.pareto(alpha, 600_000) + 1) * mu
        t1 = t1[t1 > L][:100_000]
        y = (RNG.pareto(alpha, len(t1)) + 1) * mu
        mc = (2 * np.minimum((1 - s) * t1, y)).mean()
        got = float(ref.sda_tau(alpha, s, f32([sigma]), f32([2.0]))[0, 0])
        assert abs(got - mc) < 0.02

    def test_theorem3_c_star_is_2(self):
        """Under Pareto, duplicating exactly once minimizes tau for sigma > 1."""
        sg = f32(grids.sigma_grid())
        cc = f32(np.arange(1, 9))
        tau = np.asarray(ref.sda_tau(2.0, 0.1, sg, cc))
        sel = np.asarray(sg) > 1.0
        assert (np.argmin(tau[sel], axis=1) == 1).all()  # index 1 <-> c = 2

    def test_theorem3_sigma_star(self):
        """sigma* ~ 1 + sqrt(2)/2 ~ 1.707 for alpha = 2, independent of s."""
        sg = f32(grids.sigma_grid())
        cc = f32(np.arange(1, 9))
        for s in (0.1, 0.3):
            er = np.asarray(ref.sda_resource(2.0, s, sg, cc))
            tau = np.asarray(ref.sda_tau(2.0, s, sg, cc))
            picked = er[np.arange(len(sg)), np.argmin(tau, axis=1)]
            sigma_star = float(np.asarray(sg)[np.argmin(picked)])
            assert abs(sigma_star - (1 + np.sqrt(2) / 2)) < 0.1

    def test_kernel_matches_ref(self):
        sg = f32(grids.sigma_grid())
        cc = f32(np.arange(1, 9))
        for alpha, s in [(2.0, 0.1), (3.0, 0.25), (1.5, 0.4)]:
            a = np.asarray(ref.sda_tau(alpha, s, sg, cc))
            b = np.asarray(quadrature.sda_tau(alpha, s, sg, cc))
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(alpha=st.floats(1.3, 5.0), s=st.floats(0.05, 0.6))
    def test_kernel_matches_ref_hypothesis(self, alpha, s):
        sg = f32(grids.sigma_grid())
        cc = f32(np.arange(1, 9))
        a = np.asarray(ref.sda_tau(alpha, s, sg, cc))
        b = np.asarray(quadrature.sda_tau(alpha, s, sg, cc))
        assert np.isfinite(a).all() and (a > 0).all()
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# ESE resource curve (Eq.30-33, Fig. 4)
# ---------------------------------------------------------------------------


class TestEseResource:
    def test_large_sigma_no_speculation(self):
        """sigma -> inf: nothing is duplicated, E[R] -> E[x] = 1."""
        got = float(ref.ese_resource(2.0, f32([50.0]))[0])
        assert abs(got - 1.0) < 0.02

    def test_monte_carlo(self):
        alpha, sigma, mu = 2.0, 1.7, 0.5
        x = (RNG.pareto(alpha, 1_000_000) + 1) * mu
        ask = RNG.uniform(0, x)
        t_new = (RNG.pareto(alpha, len(x)) + 1) * mu
        dup = (x - ask) > sigma
        r = np.where(dup, ask + 2 * np.minimum(x - ask, t_new), x)
        got = float(ref.ese_resource(alpha, f32([sigma]))[0])
        assert abs(got - r.mean()) < 0.01

    def test_optimum_location(self):
        """Fig. 4: sigma* in [1.6, 2.1] for alpha in {2..5}, and the gain
        shrinks as alpha grows."""
        sg = f32(grids.sigma_grid())
        gains = []
        for alpha in (2.0, 3.0, 4.0, 5.0):
            er = np.asarray(ref.ese_resource(alpha, sg))
            i = int(np.argmin(er))
            assert 1.5 <= float(np.asarray(sg)[i]) <= 2.2, alpha
            gains.append(1.0 - er[i])
        assert all(a > b for a, b in zip(gains, gains[1:]))

    def test_kernel_matches_ref(self):
        sg = f32(grids.sigma_grid())
        for alpha in (2.0, 3.0, 4.5):
            a = np.asarray(ref.ese_resource(alpha, sg))
            b = np.asarray(quadrature.ese_resource(alpha, sg))
            np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(alpha=st.floats(1.4, 6.0))
    def test_kernel_matches_ref_hypothesis(self, alpha):
        sg = f32(grids.sigma_grid())
        a = np.asarray(ref.ese_resource(alpha, sg))
        b = np.asarray(quadrature.ese_resource(alpha, sg))
        assert np.isfinite(a).all()
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)
