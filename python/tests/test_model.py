"""Model-level tests: P2 gradient projection behaviour, pallas-vs-oracle
agreement of the full lowered graphs, and the Fig. 1 convergence scenario.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import grids, ref


def f32(x):
    return jnp.asarray(np.asarray(x, dtype=np.float32))


def make_batch(mus, ms, n_avail, gamma=0.01, r=8.0, alpha=2.0, ages=None):
    B = grids.B
    mu = np.zeros(B, np.float32)
    m = np.zeros(B, np.float32)
    age = np.zeros(B, np.float32)
    mask = np.zeros(B, np.float32)
    mu[: len(mus)] = mus
    m[: len(ms)] = ms
    if ages is not None:
        age[: len(ages)] = ages
    mask[: len(mus)] = 1.0
    params = np.array([n_avail, gamma, r, alpha], np.float32)
    return tuple(map(f32, (mu, m, age, mask, params)))


FIG1 = make_batch([1, 2, 1, 2], [10, 20, 5, 10], 100.0)


class TestP2Solve:
    def test_fig1_converges(self):
        """Fig. 1 scenario: the averaged iterates settle to a fixed point."""
        c_bar, nu_tr = model.p2_solve_traced(*FIG1, use_pallas=False)
        c_bar = np.asarray(c_bar)
        tail_delta = np.abs(c_bar[-1, :4] - c_bar[-40, :4]).max()
        assert tail_delta < 0.05
        assert np.isfinite(np.asarray(nu_tr)).all()

    def test_fig1_capacity(self):
        """Converged allocation respects the capacity constraint (approx)."""
        c, nu, obj = model.p2_solve(*FIG1, use_pallas=False)
        used = float(jnp.sum(c * FIG1[1] * FIG1[3]))
        assert used <= 100.0 * 1.05
        assert float(nu) >= 0.0
        assert np.isfinite(float(obj))

    def test_fig1_beats_no_cloning(self):
        """The optimized allocation has higher utility than c = 1."""
        mu, m, age, mask, params = FIG1
        table, cg = model._p2_table(mu, m, age, params[1], params[3], False)
        c, _, _ = model.p2_solve(*FIG1, use_pallas=False)
        idx = np.abs(np.asarray(cg)[None, :] - np.asarray(c)[:, None]).argmin(1)
        msk = np.asarray(mask).astype(bool)
        opt = np.asarray(table)[np.arange(grids.B), idx][msk].sum()
        base = np.asarray(table)[:, 0][msk].sum()
        assert opt > base

    def test_ample_capacity_hits_r(self):
        """With far more machines than tasks, every job clones up to r."""
        batch = make_batch([1.0], [4], 4000.0, gamma=1e-4, r=8.0)
        c, nu, _ = model.p2_solve(*batch, use_pallas=False)
        assert float(c[0]) >= 7.5
        assert float(nu) < 1e-3

    def test_expensive_resource_stays_low(self):
        """With a huge gamma, cloning is not worth it: c stays at 1."""
        batch = make_batch([1.0, 2.0], [10, 10], 1000.0, gamma=100.0)
        c, _, _ = model.p2_solve(*batch, use_pallas=False)
        np.testing.assert_allclose(np.asarray(c[:2]), 1.0, atol=1e-6)

    def test_masked_rows_zero(self):
        c, _, _ = model.p2_solve(*FIG1, use_pallas=False)
        assert (np.asarray(c[4:]) == 0.0).all()

    def test_pallas_matches_oracle(self):
        c_a, nu_a, obj_a = model.p2_solve(*FIG1, use_pallas=True)
        c_b, nu_b, obj_b = model.p2_solve(*FIG1, use_pallas=False)
        np.testing.assert_allclose(np.asarray(c_a), np.asarray(c_b), atol=1e-4)
        assert abs(float(nu_a) - float(nu_b)) < 1e-4
        assert abs(float(obj_a) - float(obj_b)) < 1e-2

    @settings(max_examples=10, deadline=None)
    @given(
        njobs=st.integers(1, grids.B),
        headroom=st.floats(1.05, 5.0),
        gamma=st.floats(0.001, 0.1),
        seed=st.integers(0, 2**31),
    )
    def test_feasibility_hypothesis(self, njobs, headroom, gamma, seed):
        rng = np.random.default_rng(seed)
        mus = rng.uniform(0.5, 2.0, njobs)
        ms = rng.integers(1, 101, njobs)
        # Algorithm 1 only solves P2 when sum(m_i) < N(l); respect that.
        n_avail = float(ms.sum()) * headroom
        batch = make_batch(mus, ms, n_avail, gamma=gamma)
        c, nu, obj = model.p2_solve(*batch, use_pallas=False)
        c = np.asarray(c)
        assert np.isfinite(c).all()
        # bounds: active rows in [1, r], padded rows 0
        assert (c[:njobs] >= 1.0 - 1e-5).all() and (c[:njobs] <= 8.0 + 1e-5).all()
        # approximate complementary slackness: if the price settled at ~0,
        # capacity is not binding; otherwise usage is within 10% of N
        used = float((c[:njobs] * ms).sum())
        if float(nu) > 1e-3:
            assert used <= n_avail * 1.10


class TestSigmaCurve:
    def test_pallas_matches_oracle(self):
        sg_a, er_a = model.sigma_curve(f32([2.0]), use_pallas=True)
        sg_b, er_b = model.sigma_curve(f32([2.0]), use_pallas=False)
        np.testing.assert_allclose(np.asarray(er_a), np.asarray(er_b), atol=2e-4)
        np.testing.assert_allclose(np.asarray(sg_a), np.asarray(sg_b))

    def test_grid_matches_statics(self):
        sg, _ = model.sigma_curve(f32([2.0]), use_pallas=False)
        np.testing.assert_allclose(np.asarray(sg), grids.sigma_grid())


class TestSdaOpt:
    def test_tables_shape_and_theorem3(self):
        tau, er = model.sda_opt(f32([2.0, 0.1]), use_pallas=False)
        tau, er = np.asarray(tau), np.asarray(er)
        assert tau.shape == (grids.S, model.SDA_C)
        sg = grids.sigma_grid()
        sel = sg > 1.0
        assert (np.argmin(tau[sel], axis=1) == 1).all()
        picked = er[np.arange(len(sg)), np.argmin(tau, axis=1)]
        assert abs(float(sg[np.argmin(picked)]) - 1.707) < 0.1

    def test_pallas_matches_oracle(self):
        a = model.sda_opt(f32([2.0, 0.1]), use_pallas=True)
        b = model.sda_opt(f32([2.0, 0.1]), use_pallas=False)
        for x, y in zip(a, b):
            np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=2e-4)
