"""AOT path tests: every entry point lowers to parseable HLO text with the
shapes the manifest advertises, and the manifest matches grids.py statics.
"""

import json
import os
import subprocess
import sys

import jax
import pytest

from compile import aot, model
from compile.kernels import grids


@pytest.fixture(scope="module")
def eps():
    return aot.entry_points()


class TestLowering:
    @pytest.mark.parametrize("name", ["p2_solver", "p2_trace", "sigma_curve", "sda_opt"])
    def test_lowers_to_hlo_text(self, eps, name):
        fn, example, entry = eps[name]
        lowered = jax.jit(fn).lower(*example)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert "HloModule" in text
        # no Mosaic custom-calls may survive (interpret=True requirement)
        assert "tpu_custom_call" not in text
        assert "mosaic" not in text.lower()

    def test_manifest_entries_cover_all(self, eps):
        assert set(eps) == {"p2_solver", "p2_trace", "sigma_curve", "sda_opt"}

    def test_example_shapes_match_manifest(self, eps):
        for name, (fn, example, entry) in eps.items():
            declared = [tuple(i["shape"]) for i in entry["inputs"]]
            actual = [tuple(a.shape) for a in example]
            assert declared == actual, name

    def test_output_shapes_match_manifest(self, eps):
        for name, (fn, example, entry) in eps.items():
            out = jax.eval_shape(fn, *example)
            leaves = jax.tree_util.tree_leaves(out)
            declared = [tuple(o["shape"]) for o in entry["outputs"]]
            actual = [tuple(l.shape) for l in leaves]
            assert declared == actual, name


class TestCli:
    def test_aot_writes_artifacts(self, tmp_path):
        """End-to-end: the module CLI writes the artifact + manifest for the
        cheapest entry point."""
        # run from python/ regardless of where pytest was invoked
        pkg_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
             "--only", "sigma_curve"],
            check=True,
            cwd=pkg_root,
        )
        man = json.loads((tmp_path / "manifest.json").read_text())
        assert man["statics"]["batch"] == grids.B
        assert man["statics"]["p2_iters"] == grids.P2_ITERS
        assert "sigma_curve" in man["artifacts"]
        hlo = (tmp_path / "sigma_curve.hlo.txt").read_text()
        assert "ENTRY" in hlo
